"""Hot-path microbenchmark suite: the repo's performance trajectory.

Three benchmarks, registered in the stage registry under kind="benchmark"
(:mod:`repro.perf` registers them on import) and dispatched by both
``python -m repro bench`` and ``python -m benchmarks.perf.run``:

* ``perf_feeder`` — dependency-aware drain throughput (nodes/sec) across
  trace sizes and window sizes; exercises the O(1) ``in_flight`` counter and
  bounded bookkeeping inside the elastic-refill loop.
* ``perf_sim``    — simulator events/sec on the mixed AR×A2A scenario
  (paper §5.3) across trace sizes and rank counts, optionally against the
  frozen pre-optimization engine (``repro.sim.ReferenceSimulator``) so the
  speedup columns are measured, not asserted.
* ``perf_chkb``   — CHKB encode / decode throughput (MB/s and nodes/s),
  v3 row blocks vs v4 columnar blocks, including the column-level decode
  path (``NodeColumns`` — no ETNode materialization) and the real columnar
  consumer (:func:`repro.core.analysis.columnar_summary`).
* ``perf_netmodel`` — link-fidelity network model vs analytic: simulator
  wall time in both modes on the same mixed workload (the routed mode must
  stay within 2x of analytic at 100k-node x 8-rank scale), routing-table
  build rate, and the model's memoization hit rate.
* ``perf_synth``  — statistical-synthesis throughput (``repro.synth``):
  profile-fit rate over the columnar path, streaming multi-rank generation
  into CHKB v4 (the ≥100k nodes/sec floor; full scale synthesizes a ≥1M-node
  8-rank workload), and a tracemalloc bounded-memory probe showing the
  generator never materializes per-rank node lists.
* ``perf_explore`` — co-design sweep engine (``repro.explore``): spec
  expansion rate (canonical hashing included) and a cold sweep vs its
  fully-cached replay — the replay must execute zero simulations.
* ``perf_faults`` — fault-injection hot-path cost (``repro.faults``):
  interleaved no-plan vs empty-plan vs chaos-plan walls on the same mixed
  workload; the gated ``empty_plan_overhead`` must stay <= 1.05 because the
  fault machinery lives entirely behind ``if fault is not None``.
* ``perf_obs`` — self-tracing telemetry cost (``repro.obs``): interleaved
  off vs timeline-recorder vs metrics-registry walls on the same mixed
  workload; both gated overhead ratios must stay <= 1.15 and the
  instrumented runs must remain bit-identical to the off run.
* ``perf_ingest`` — real-trace ingestion (``repro.ingest``): streaming
  Chrome/Kineto parse rate and standardization into an ExecutionTrace
  (correlation splice + comm classification + dependency verification
  included); the subsystem's floor is ≥100k events/sec in each stage,
  with ``end_to_end`` reporting their combined rate.
* ``perf_shard`` — sharded simulation (``repro.sim.shard``): the mixed
  workload single-process vs :class:`~repro.sim.ShardedSimulator` with
  ``jobs`` workers (events/sec both ways, speedup, and the absolute
  ``bit_identical`` contract), plus the million-rank ``serve-decode-burst``
  fleet cell streamed through :class:`~repro.sim.SynthSource` without ever
  materializing per-rank traces.  Wall-clock speedup is core-count
  dependent — the host block records ``cpu_count`` so the gate can skip
  cross-host comparisons.
* ``perf_serve`` — live benchmark service (``repro.serve_api``): HTTP
  submission-to-report latency cold and fully cached (the cached replay
  must execute zero simulations — gated absolutely) plus merged
  ``/metrics`` scrape throughput, all over a real ephemeral-port daemon
  with scrapes racing the running sweep.

Results aggregate into a JSON document written to ``BENCH_perf.json`` at the
repo root (see :func:`run_suite` / :func:`write_bench`).  Wall-clock numbers
are machine-dependent; the ``*_speedup`` ratios are the stable signal.
"""
from __future__ import annotations

import json
import os
import platform
import sys
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..core import generator
from ..core.feeder import ETFeeder
from ..core.schema import ExecutionTrace
from ..core.serialization import (_decode_block_v3, _decode_block_v4,
                                  _decode_block_v4_columns, _encode_block_v3,
                                  _encode_block_v4)

SCALES = ("smoke", "full")

#: per-scale knobs: (feeder trace sizes, sim (nodes_per_rank, ranks) grid,
#: sim baseline subset, chkb trace size)
_SCALE = {
    "smoke": {
        "feeder_nodes": [10_000],
        "sim_grid": [(1_000, 4), (1_000, 8)],
        "sim_baseline": [(1_000, 8)],
        "chkb_nodes": 10_000,
        "chkb_repeat": 3,
        "netmodel_grid": [(1_000, 8)],
        "netmodel_route_n": 64,
        # world x (steps * ops/step) = 2 x 10k = 20k nodes
        "synth": {"world": 2, "steps": 50, "ops_per_step": 200,
                  "profile_nodes": 10_000},
        # 2 workloads x 4 topo x 4 world x 4 bw x 2 lat x 2 fid x 2 jitter
        "explore": {"jitter_values": 2, "iters": 4,
                    "world_sizes": [4, 8], "jobs": 2},
        "ingest_events": 20_000,
        "faults": {"grid": (2_000, 8), "repeat": 3},
        "obs": {"grid": (1_000, 8), "repeat": 3},
        "shard": {"grid": (250, 8), "jobs": 2,
                  "fleet_world": 10_000, "fleet_steps": 1,
                  "fleet_ops": 4, "fleet_jobs": 4},
        # 3 topologies x 1 world x 1 fidelity = 3-config sweep
        "serve": {"iters": 2, "world_sizes": [4],
                  "fidelities": ["analytic"], "scrapes": 100},
    },
    "full": {
        "feeder_nodes": [10_000, 100_000],
        "sim_grid": [(1_000, 4), (1_000, 8), (1_000, 16),
                     (10_000, 4), (10_000, 8), (10_000, 16),
                     (100_000, 8)],
        "sim_baseline": [(1_000, 8), (10_000, 8), (100_000, 8)],
        "chkb_nodes": 50_000,
        "chkb_repeat": 5,
        "netmodel_grid": [(10_000, 8), (100_000, 8)],
        "netmodel_route_n": 256,
        # world x (steps * ops/step) = 8 x 131072 = 1,048,576 nodes (>=1M)
        "synth": {"world": 8, "steps": 512, "ops_per_step": 256,
                  "profile_nodes": 50_000},
        # 2048-config expansion; 24-config sweep, 4-way parallel
        "explore": {"jitter_values": 4, "iters": 16,
                    "world_sizes": [4, 8, 16, 32], "jobs": 4},
        "ingest_events": 200_000,
        "faults": {"grid": (10_000, 8), "repeat": 5},
        "obs": {"grid": (10_000, 8), "repeat": 5},
        # 64 ranks x ~1.6k actual nodes/rank => >100k-node scenario, 8
        # workers; fleet: the million-rank headline cell
        "shard": {"grid": (2_000, 64), "jobs": 8,
                  "fleet_world": 1_000_000, "fleet_steps": 1,
                  "fleet_ops": 4, "fleet_jobs": 8},
        # 3 topologies x 2 worlds x 2 fidelities = 12-config sweep
        "serve": {"iters": 4, "world_sizes": [4, 8],
                  "fidelities": ["analytic", "link"], "scrapes": 500},
    },
}

_SIM_MAX_EVENTS = 200_000_000


def _cfg(scale: str) -> Dict[str, Any]:
    if scale not in _SCALE:
        raise ValueError(f"unknown scale {scale!r}; options: {SCALES}")
    return _SCALE[scale]


def _mixed_trace(nodes: int, ranks: int, rank: int = 0) -> ExecutionTrace:
    """§5.3 mixed AR×A2A MoE trace sized to ~``nodes`` nodes."""
    per_iter = 5                       # moe_mixed emits ~5 nodes per iteration
    iters = max(1, nodes // per_iter)
    return generator.moe_mixed_collectives(iters=iters, ranks=ranks,
                                           rank=rank, jitter=True)


def _chain_heavy_trace(nodes: int) -> ExecutionTrace:
    """Single-rank DP-style trace (deep chains + fan-in) for feeder drains."""
    layers = 8
    steps = max(1, nodes // (2 * layers + 1))
    return generator.dp_allreduce_pattern(steps=steps, layers=layers, ranks=8)


# ------------------------------------------------------------------- feeder
def perf_feeder(scale: str = "full", **_: Any) -> Dict[str, Any]:
    """Feeder drain throughput (nodes/sec) across trace and window sizes."""
    rows: List[Dict[str, Any]] = []
    for nodes in _cfg(scale)["feeder_nodes"]:
        et = _chain_heavy_trace(nodes)
        for window in (64, 1024):
            feeder = ETFeeder(et, window=window, policy="fifo")
            t0 = time.perf_counter()
            order = feeder.drain_order()
            dt = time.perf_counter() - t0
            rows.append({
                "nodes": len(order),
                "window": window,
                "wall_s": round(dt, 6),
                "nodes_per_sec": round(len(order) / dt, 1),
            })
    return {"drain": rows}


# ---------------------------------------------------------------- simulator
def _run_sim(engine_cls, traces, ranks: int) -> Dict[str, Any]:
    from ..sim import Fabric
    fabric = Fabric.build("switch", ranks)
    t0 = time.perf_counter()
    res = engine_cls(traces, fabric).run(max_events=_SIM_MAX_EVENTS)
    dt = time.perf_counter() - t0
    return {
        "wall_s": round(dt, 4),
        "events": res.events,
        "events_per_sec": round(res.events / dt, 1),
        "makespan_s": res.makespan_s,
        "flows": len(res.flows),
    }


def perf_sim(scale: str = "full", baseline: bool = True,
             **_: Any) -> Dict[str, Any]:
    """Simulator throughput on mixed AR×A2A scenarios; optional reference
    (pre-optimization) baseline for measured speedups."""
    from ..sim import ReferenceSimulator, Simulator
    cfg = _cfg(scale)
    baseline_grid = set(cfg["sim_baseline"]) if baseline else set()
    rows: List[Dict[str, Any]] = []
    for nodes_per_rank, ranks in cfg["sim_grid"]:
        traces = [_mixed_trace(nodes_per_rank, ranks, rank=r)
                  for r in range(ranks)]
        total = sum(len(t) for t in traces)
        row: Dict[str, Any] = {
            "scenario": "mixed_ar_a2a",
            "nodes_per_rank": nodes_per_rank,
            "ranks": ranks,
            "total_nodes": total,
            "engine": _run_sim(Simulator, traces, ranks),
        }
        if (nodes_per_rank, ranks) in baseline_grid:
            ref = _run_sim(ReferenceSimulator, traces, ranks)
            row["baseline"] = ref
            row["wall_speedup"] = round(
                ref["wall_s"] / row["engine"]["wall_s"], 2)
            row["events_per_sec_speedup"] = round(
                row["engine"]["events_per_sec"] / ref["events_per_sec"], 2)
        rows.append(row)
    return {"scenarios": rows}


# ---------------------------------------------------------------- netmodel
def perf_netmodel(scale: str = "full", **_: Any) -> Dict[str, Any]:
    """Link-fidelity network model vs analytic: wall-time ratio, routing
    precompute rate, memoization effectiveness.

    The acceptance floor for the routed mode is ``wall_ratio <= 2.0`` on the
    largest grid entry (100k nodes x 8 ranks at full scale): memoized phase
    specs + per-payload time caching keep the graph work off the hot path.
    """
    from ..sim import Fabric, Simulator

    rows: List[Dict[str, Any]] = []
    for nodes_per_rank, ranks in _cfg(scale)["netmodel_grid"]:
        traces = [_mixed_trace(nodes_per_rank, ranks, rank=r)
                  for r in range(ranks)]
        total = sum(len(t) for t in traces)
        row: Dict[str, Any] = {"scenario": "mixed_ar_a2a",
                               "nodes_per_rank": nodes_per_rank,
                               "ranks": ranks, "total_nodes": total}
        for mode in ("analytic", "link"):
            fabric = Fabric.build("switch", ranks, mode=mode)
            t0 = time.perf_counter()
            res = Simulator(traces, fabric).run(max_events=_SIM_MAX_EVENTS)
            dt = time.perf_counter() - t0
            row[mode] = {"wall_s": round(dt, 4),
                         "events_per_sec": round(res.events / dt, 1),
                         "makespan_s": res.makespan_s}
            if res.link_stats:
                row["time_cache"] = res.link_stats["time_cache"]
        row["wall_ratio"] = round(row["link"]["wall_s"]
                                  / row["analytic"]["wall_s"], 3)
        rows.append(row)

    # routing-table precompute: all-pairs paths on the big torus
    from ..core.infragraph import tpu_pod_2d
    n = _cfg(scale)["netmodel_route_n"]
    d = int(n ** 0.5)
    g = tpu_pod_2d(d, n // d)
    t0 = time.perf_counter()
    routes = g.routing()
    pairs = 0
    for src in g.npus:
        for dst in g.npus:
            if src != dst:
                routes.path(src, dst)
                pairs += 1
    dt = time.perf_counter() - t0
    return {"scenarios": rows,
            "routing": {"graph": g.name, "npus": g.num_npus,
                        "pairs": pairs, "wall_s": round(dt, 4),
                        "pairs_per_sec": round(pairs / dt, 1)}}


# --------------------------------------------------------------------- chkb
def _time_it(fn, *args, repeat: int = 3) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best


def perf_chkb(scale: str = "full", **_: Any) -> Dict[str, Any]:
    """CHKB v3 vs v4 block encode/decode throughput (MB/s, nodes/s)."""
    cfg = _cfg(scale)
    repeat = cfg["chkb_repeat"]
    et = _chain_heavy_trace(cfg["chkb_nodes"])
    nodes = et.sorted_nodes()
    n = len(nodes)
    b3 = _encode_block_v3(nodes)
    b4 = _encode_block_v4(nodes)

    def row(label: str, seconds: float, payload: int) -> Dict[str, Any]:
        return {"path": label, "wall_s": round(seconds, 5),
                "mb_per_sec": round(payload / seconds / 1e6, 2),
                "nodes_per_sec": round(n / seconds, 1)}

    enc3 = _time_it(_encode_block_v3, nodes, repeat=repeat)
    enc4 = _time_it(_encode_block_v4, nodes, repeat=repeat)
    dec3 = _time_it(_decode_block_v3, b3, repeat=repeat)
    dec4_nodes = _time_it(_decode_block_v4, b4, repeat=repeat)
    dec4_cols = _time_it(_decode_block_v4_columns, b4, repeat=repeat)

    # end-to-end file paths (compressed, default codec) + the columnar
    # consumer vs the SAME numeric summary over materialized nodes of the
    # SAME v4 file — isolating columns-vs-objects, not codec or workload
    import os
    import tempfile
    from ..core.analysis import columnar_summary
    from ..core.serialization import ChkbReader, load, save

    def node_summary(path: str) -> None:
        """columnar_summary's numeric workload via full node objects."""
        with ChkbReader(path) as r:
            edges = total_bytes = 0
            duration = 0.0
            for node in r.iter_nodes():
                edges += (len(node.ctrl_deps) + len(node.data_deps)
                          + len(node.sync_deps))
                total_bytes += node.comm_bytes
                duration += node.duration_micros

    with tempfile.TemporaryDirectory() as tmp:
        p3 = os.path.join(tmp, "t3.chkb")
        p4 = os.path.join(tmp, "t4.chkb")
        save(et, p3, version=3)
        save(et, p4, version=4)
        size3 = os.path.getsize(p3)
        size4 = os.path.getsize(p4)
        load3 = _time_it(load, p3, repeat=repeat)
        load4 = _time_it(load, p4, repeat=repeat)
        summary_cols = _time_it(columnar_summary, p4, repeat=repeat)
        summary_nodes = _time_it(node_summary, p4, repeat=repeat)

    def frow(label: str, seconds: float, file_bytes: int) -> Dict[str, Any]:
        return {"path": label, "wall_s": round(seconds, 5),
                "file_mb_per_sec": round(file_bytes / seconds / 1e6, 2),
                "nodes_per_sec": round(n / seconds, 1)}

    return {
        "block_nodes": n,
        "block_bytes": {"v3": len(b3), "v4": len(b4)},
        "file_bytes": {"v3": size3, "v4": size4},
        "encode": [row("v3_rows", enc3, len(b3)),
                   row("v4_columnar", enc4, len(b4))],
        "decode": [row("v3_rows_to_nodes", dec3, len(b3)),
                   row("v4_columnar_to_nodes", dec4_nodes, len(b4)),
                   row("v4_columnar_to_columns", dec4_cols, len(b4))],
        "file": [frow("load_v3", load3, size3),
                 frow("load_v4", load4, size4),
                 frow("columnar_summary_v4", summary_cols, size4),
                 frow("node_summary_v4", summary_nodes, size4)],
        "encode_speedup": round(enc3 / enc4, 2),
        # headline: block decode to the format's usable in-memory structure.
        # v4's structure IS the columns (NodeColumns) — object
        # materialization is optional and measured separately above.
        "block_decode_speedup": round(dec3 / dec4_cols, 2),
        "node_decode_speedup": round(dec3 / dec4_nodes, 2),
        # same file, same numeric summary: columns vs node objects
        "columnar_summary_speedup": round(summary_nodes / summary_cols, 2),
    }


# -------------------------------------------------------------------- synth
def perf_synth(scale: str = "full", **_: Any) -> Dict[str, Any]:
    """repro.synth throughput: profile fit, streaming generation, memory.

    ``generate.nodes_per_sec`` is the headline (sustained nodes/sec into
    CHKB v4 across all ranks, file writes included; the subsystem's floor is
    100k/s).  ``bounded_memory.peak_mb`` is the tracemalloc peak of a
    single-rank synthesis — it stays O(block), orders of magnitude below the
    materialized trace, because the generator streams through ``ChkbWriter``
    and never holds a node list.
    """
    import os
    import tempfile
    import tracemalloc

    from ..core.serialization import save
    from ..synth import profile_chkb, synthesize, synthesize_rank

    cfg = _cfg(scale)["synth"]
    # source workload: mixed AR x A2A, profiled off a v4 file so the fit
    # rides the columnar path exactly as production profiling would
    src = _mixed_trace(cfg["profile_nodes"], 8)
    with tempfile.TemporaryDirectory() as tmp:
        src_path = os.path.join(tmp, "src.chkb")
        save(src, src_path, version=4)
        t0 = time.perf_counter()
        profile = profile_chkb([src_path])
        fit_s = time.perf_counter() - t0

        out_dir = os.path.join(tmp, "synth")
        t0 = time.perf_counter()
        man = synthesize(profile, out_dir, world_size=cfg["world"],
                         steps=cfg["steps"], ops_per_step=cfg["ops_per_step"])
        gen_s = time.perf_counter() - t0

        tracemalloc.start()
        synthesize_rank(profile, os.path.join(tmp, "probe.chkb"), rank=0,
                        world_size=cfg["world"], steps=cfg["steps"] // 4,
                        ops_per_step=cfg["ops_per_step"],
                        seed=1)
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()

    return {
        "profile": {
            "source_nodes": len(src),
            "wall_s": round(fit_s, 4),
            "nodes_per_sec": round(len(src) / fit_s, 1),
            "fingerprint": profile.fingerprint(),
        },
        "generate": {
            "world_size": man["world_size"],
            "ranks_written": len(man["paths"]),
            "total_nodes": man["total_nodes"],
            "bytes_written": man["bytes_written"],
            "wall_s": round(gen_s, 4),
            "nodes_per_sec": round(man["total_nodes"] / gen_s, 1),
        },
        "bounded_memory": {
            "nodes": cfg["steps"] // 4 * cfg["ops_per_step"],
            "peak_mb": round(peak / 1e6, 2),
        },
    }


# ------------------------------------------------------------------ explore
def perf_explore(scale: str = "full", **_: Any) -> Dict[str, Any]:
    """Co-design sweep engine: grid expansion rate + cold-vs-cached sweeps.

    ``expand.configs_per_sec`` prices the spec-to-RunConfig pipeline
    (canonical hashing included); the sweep rows compare a cold run against
    a fully-cached replay of the same spec — ``cached_executed`` must be 0
    (the replay performs zero simulations) and ``cache_speedup`` is the
    headline win for iterative co-design studies.
    """
    import tempfile

    from ..explore import ExperimentSpec, run_sweep

    cfg = _cfg(scale)["explore"]
    big = ExperimentSpec.from_dict({
        "name": "perf-expand",
        "workloads": [{"pattern": "moe_mixed",
                       "args": {"mode": m, "iters": 2}}
                      for m in ("allreduce", "alltoall")],
        "axes": {"topology": ["ring", "switch", "clos", "fully_connected"],
                 "world_size": [4, 8, 16, 32],
                 "link_bw": [2.5e10, 5e10, 1e11, 2e11],
                 "latency_s": [1e-6, 2e-6],
                 "fidelity": ["analytic", "link"],
                 "jitter": [0.0, 0.1, 0.2, 0.3][:cfg["jitter_values"]]},
    })
    t0 = time.perf_counter()
    configs = big.expand()
    expand_s = time.perf_counter() - t0

    sweep_spec = ExperimentSpec.from_dict({
        "name": "perf-sweep",
        "workloads": [{"pattern": "moe_mixed",
                       "args": {"mode": "mixed", "iters": cfg["iters"]}}],
        "axes": {"topology": ["ring", "switch", "clos"],
                 "world_size": cfg["world_sizes"],
                 "fidelity": ["analytic", "link"]},
    })
    with tempfile.TemporaryDirectory() as tmp:
        t0 = time.perf_counter()
        cold = run_sweep(sweep_spec, jobs=cfg["jobs"], cache_dir=tmp)
        cold_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        warm = run_sweep(sweep_spec, jobs=cfg["jobs"], cache_dir=tmp)
        warm_s = time.perf_counter() - t0

    return {
        "expand": {
            "configs": len(configs),
            "wall_s": round(expand_s, 4),
            "configs_per_sec": round(len(configs) / expand_s, 1),
        },
        "sweep": {
            "configs": len(cold.rows),
            "jobs": cfg["jobs"],
            "cold_wall_s": round(cold_s, 4),
            "cold_runs_per_sec": round(len(cold.rows) / cold_s, 1),
            "cached_wall_s": round(warm_s, 4),
            "cached_runs_per_sec": round(len(warm.rows) / warm_s, 1),
            "cached_executed": warm.executed,   # must be 0: replay = cache
            "cache_speedup": round(cold_s / warm_s, 2),
        },
    }


# ------------------------------------------------------------------- faults
def perf_faults(scale: str = "full", **_: Any) -> Dict[str, Any]:
    """Fault-injection hot-path cost: an empty plan must be free.

    Three interleaved best-of-N runs of the same mixed workload: no plan,
    an empty :class:`~repro.faults.FaultPlan` (normalizes to no runtime —
    the bit-identity contract), and an MTBF-generated chaos plan under the
    ``shrink`` policy.  ``empty_plan_overhead`` (empty wall / no-plan wall)
    is the gated number: the fault machinery lives entirely behind
    ``if fault is not None`` so the fault-free path pays nothing (<=5%).
    """
    from ..faults import FaultPlan
    from ..sim import Fabric, SimConfig, Simulator

    cfg = _cfg(scale)["faults"]
    nodes_per_rank, ranks = cfg["grid"]
    repeat = cfg["repeat"]
    traces = [_mixed_trace(nodes_per_rank, ranks, rank=r)
              for r in range(ranks)]
    fabric = Fabric.build("switch", ranks)
    chaos = FaultPlan.generate(
        world_size=ranks, duration_s=1.0, seed=7,
        slowdown_mtbf_s=0.2, slowdown_factor=3.0,
        crash_mtbf_s=5.0, restart_after_s=0.05,
        policy="shrink", collective_timeout_s=0.01, name="perf-chaos")
    variants = {
        "no_plan": None,
        "empty_plan": FaultPlan(name="empty").to_dict(),
        "chaos_plan": chaos.to_dict(),
    }

    best: Dict[str, float] = {k: float("inf") for k in variants}
    results: Dict[str, Any] = {}
    overhead = float("inf")
    for _rep in range(repeat):
        walls: Dict[str, float] = {}
        for label, plan in variants.items():     # interleaved: fair clocks
            sim = Simulator(traces, fabric, SimConfig(fault_plan=plan))
            t0 = time.perf_counter()
            res = sim.run(max_events=_SIM_MAX_EVENTS)
            walls[label] = time.perf_counter() - t0
            best[label] = min(best[label], walls[label])
            results[label] = res
        # pair the ratio within one repetition (machine drift cancels); a
        # *systematic* overhead shows up in every pair, so min is honest
        overhead = min(overhead, walls["empty_plan"] / walls["no_plan"])

    none_r, empty_r = results["no_plan"], results["empty_plan"]
    chaos_r = results["chaos_plan"]
    rows = {label: {"wall_s": round(best[label], 4),
                    "events": results[label].events,
                    "events_per_sec": round(results[label].events
                                            / best[label], 1),
                    "makespan_s": results[label].makespan_s}
            for label in variants}
    fs = chaos_r.fault_stats or {}
    return {
        "scenario": "mixed_ar_a2a",
        "nodes_per_rank": nodes_per_rank,
        "ranks": ranks,
        "runs": rows,
        # the gated number: empty plan must cost nothing (<= 1.05);
        # min-over-reps of the within-rep ratio, robust to machine drift
        "empty_plan_overhead": round(overhead, 3),
        # the correctness side of the same contract
        "empty_plan_bit_identical": (
            empty_r.makespan_s == none_r.makespan_s
            and empty_r.events == none_r.events
            and empty_r.per_rank_finish_s == none_r.per_rank_finish_s),
        "chaos": {
            "plan_events": len(chaos.events),
            "makespan_inflation_pct": round(
                100.0 * (chaos_r.makespan_s / none_r.makespan_s - 1.0), 2)
            if none_r.makespan_s else None,
            "timeouts": fs.get("timeouts"),
            "collectives_shrunk": fs.get("collectives_shrunk"),
            "rejoins": fs.get("rejoins"),
            "aborted": chaos_r.aborted,
        },
    }


# ---------------------------------------------------------------------- obs
def perf_obs(scale: str = "full", **_: Any) -> Dict[str, Any]:
    """Self-tracing telemetry cost: recording must be cheap, off must be free.

    Three interleaved best-of-N runs of the same mixed workload: no
    instrumentation, a :class:`~repro.obs.TimelineRecorder`, and a
    :class:`~repro.obs.MetricsRegistry`.  The gated numbers:
    ``timeline_overhead`` and ``metrics_overhead`` (instrumented wall /
    off wall, min of the within-rep ratios) must stay <= 1.15, and
    ``instrumented_identical`` must hold — recording observes the
    schedule, it never perturbs it.  The recorder-off path costs nothing
    by construction (every hook sits behind ``if x is not None``), which
    the off row's events/sec documents against the baseline.
    """
    import os as _os
    import tempfile as _tempfile

    from ..obs import MetricsRegistry, TimelineRecorder
    from ..sim import Fabric, SimConfig, Simulator

    cfg = _cfg(scale)["obs"]
    nodes_per_rank, ranks = cfg["grid"]
    repeat = cfg["repeat"]
    traces = [_mixed_trace(nodes_per_rank, ranks, rank=r)
              for r in range(ranks)]
    fabric = Fabric.build("switch", ranks)
    variants = ("off", "timeline", "metrics")

    best: Dict[str, float] = {k: float("inf") for k in variants}
    results: Dict[str, Any] = {}
    tl_overhead = m_overhead = float("inf")
    for _rep in range(repeat):
        walls: Dict[str, float] = {}
        for label in variants:               # interleaved: fair clocks
            sc = SimConfig()
            if label == "timeline":
                sc.timeline = TimelineRecorder()
            elif label == "metrics":
                sc.metrics = MetricsRegistry()
            sim = Simulator(traces, fabric, sc)
            t0 = time.perf_counter()
            results[label] = sim.run(max_events=_SIM_MAX_EVENTS)
            walls[label] = time.perf_counter() - t0
            best[label] = min(best[label], walls[label])
        # pair ratios within one repetition (machine drift cancels); a
        # systematic overhead shows up in every pair, so min is honest
        tl_overhead = min(tl_overhead, walls["timeline"] / walls["off"])
        m_overhead = min(m_overhead, walls["metrics"] / walls["off"])

    off_r = results["off"]
    rows = {label: {"wall_s": round(best[label], 4),
                    "events": results[label].events,
                    "events_per_sec": round(results[label].events
                                            / best[label], 1)}
            for label in variants}

    # export cost: Chrome-trace JSON serialization of the recorded timeline
    rec = results["timeline"].timeline
    fd, tmp = _tempfile.mkstemp(suffix=".json")
    _os.close(fd)
    try:
        t0 = time.perf_counter()
        rec.export_chrome(tmp)
        export_s = time.perf_counter() - t0
        export_bytes = _os.path.getsize(tmp)
    finally:
        _os.unlink(tmp)

    return {
        "scenario": "mixed_ar_a2a",
        "nodes_per_rank": nodes_per_rank,
        "ranks": ranks,
        "runs": rows,
        # the gated numbers: recording stays within 15% of the off run
        "timeline_overhead": round(tl_overhead, 3),
        "metrics_overhead": round(m_overhead, 3),
        # the correctness side of the contract: recording never perturbs
        # the schedule
        "instrumented_identical": all(
            r.makespan_s == off_r.makespan_s
            and r.events == off_r.events
            and r.per_rank_finish_s == off_r.per_rank_finish_s
            for r in (results["timeline"], results["metrics"])),
        "export": {
            "spans": rec.n_spans,
            "flows": rec.n_flows,
            "wall_s": round(export_s, 4),
            "spans_per_sec": round(rec.n_spans / export_s, 1)
            if export_s > 0 else None,
            "bytes": export_bytes,
        },
    }


# ------------------------------------------------------------------- ingest
def _synth_kineto_doc(n_events: int) -> bytes:
    """Synthetic Kineto document sized to ``n_events``: host op + runtime
    launch + correlated kernel triplets, with a periodic NCCL collective
    carrying full comm args — the shapes the hot splice path has to chew."""
    ev: List[Dict[str, Any]] = []
    t = 0
    corr = 0
    while len(ev) < n_events:
        t += 100
        corr += 1
        ev.append({"ph": "X", "name": "aten::mm", "cat": "cpu_op",
                   "pid": 1, "tid": 2, "ts": t, "dur": 30,
                   "args": {"External id": corr}})
        ev.append({"ph": "X", "name": "cudaLaunchKernel",
                   "cat": "cuda_runtime", "pid": 1, "tid": 2,
                   "ts": t + 35, "dur": 5, "args": {"correlation": corr}})
        if corr % 16:
            ev.append({"ph": "X", "name": "sgemm_128x64_tn", "cat": "kernel",
                       "pid": 0, "tid": 7, "ts": t + 50, "dur": 40,
                       "args": {"correlation": corr}})
        else:
            ev.append({"ph": "X",
                       "name": "ncclDevKernel_AllReduce_Sum_f32_RING_LL",
                       "cat": "kernel", "pid": 0, "tid": 7,
                       "ts": t + 50, "dur": 80,
                       "args": {"correlation": corr,
                                "In msg nelems": 262144, "dtype": "float32",
                                "Process Group Ranks": "[0, 1, 2, 3]",
                                "Process Group Name": "0"}})
    doc = {"traceEvents": ev,
           "distributedInfo": {"rank": 0, "world_size": 4}}
    return json.dumps(doc).encode("utf-8")


def perf_ingest(scale: str = "full", **_: Any) -> Dict[str, Any]:
    """Chrome/Kineto ingestion throughput (events/sec).

    ``parse`` (raw JSON bytes to the KEvent stream) and ``standardize``
    (KEvents to a verified ExecutionTrace: host nesting, correlation
    splice, comm classification, ``verify_and_clean``) must each clear the
    100k events/sec floor; ``end_to_end`` is their combined rate.  The
    topological-by-construction emission discipline is what keeps
    standardization over the floor — no canonicalize pass on the hot path.
    """
    from ..ingest import parse_chrome_trace, standardize_chrome

    n = _cfg(scale)["ingest_events"]
    payload = _synth_kineto_doc(n)

    t0 = time.perf_counter()
    ct = parse_chrome_trace(payload)
    parse_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    et, report = standardize_chrome(ct)
    std_s = time.perf_counter() - t0

    events = ct.events_seen
    total_s = parse_s + std_s
    return {
        "events": events,
        "payload_mb": round(len(payload) / 1e6, 2),
        "parse": {
            "wall_s": round(parse_s, 4),
            "events_per_sec": round(events / parse_s, 1),
            "mb_per_sec": round(len(payload) / parse_s / 1e6, 2),
        },
        "standardize": {
            "wall_s": round(std_s, 4),
            "events_per_sec": round(events / std_s, 1),
            "nodes_out": len(et),
            "comm_nodes": report.comm_nodes,
            "corr_resolved": report.corr_resolved,
        },
        "end_to_end": {
            "wall_s": round(total_s, 4),
            "events_per_sec": round(events / total_s, 1),
        },
    }


# -------------------------------------------------------------------- shard
def _same_result(a: Any, b: Any) -> bool:
    """Full SimResult equality — the sharded engine's bit-identity contract."""
    return (a.makespan_s == b.makespan_s
            and a.per_rank_finish_s == b.per_rank_finish_s
            and a.collective_time_s == b.collective_time_s
            and a.collective_bytes == b.collective_bytes
            and a.flows == b.flows
            and a.compute_busy_s == b.compute_busy_s
            and a.exposed_comm_s == b.exposed_comm_s
            and a.link_util_timeline == b.link_util_timeline
            and a.events == b.events
            and a.link_stats == b.link_stats
            and a.aborted == b.aborted
            and a.abort_reason == b.abort_reason
            and a.fault_stats == b.fault_stats)


def perf_shard(scale: str = "full", **_: Any) -> Dict[str, Any]:
    """Sharded-simulation throughput vs the single-process engine.

    Two cells.  ``grid``: the mixed AR×A2A scenario run by the
    single-process engine and by :class:`~repro.sim.ShardedSimulator` with
    ``jobs`` spawn-context workers; reports events/sec both ways, the
    speedup ratio, and the absolute ``bit_identical`` contract (the
    sharded run must reproduce the single-process ``SimResult`` exactly —
    gated regardless of host).  ``fleet``: the ``serve-decode-burst``
    synthetic fleet at ``fleet_world`` ranks streamed through
    :class:`~repro.sim.SynthSource` — per-rank traces are generated inside
    the workers, never materialized in the parent; at full scale this is
    the million-rank headline cell.  Wall-clock speedup is core-count
    dependent: on a single-core host the sharded run is expected to be
    *slower* (process + replay overhead with no parallelism to buy it
    back), which is why ``cpu_count`` is recorded here and in the host
    block, and why ``scripts/perf_gate.py`` skips the shard rate rows when
    the baseline's core count differs from the current host's.
    """
    from ..sim import (Fabric, ShardedSimulator, SimConfig, Simulator,
                       SynthSource)
    from ..synth import get_scenario

    cfg = _cfg(scale)["shard"]
    nodes_per_rank, ranks = cfg["grid"]
    jobs = cfg["jobs"]
    traces = [_mixed_trace(nodes_per_rank, ranks, rank=r)
              for r in range(ranks)]
    total_nodes = sum(len(t) for t in traces)

    t0 = time.perf_counter()
    single = Simulator(traces, Fabric.build("switch", ranks),
                       SimConfig()).run(max_events=_SIM_MAX_EVENTS)
    single_s = time.perf_counter() - t0

    sharded_sim = ShardedSimulator(traces, Fabric.build("switch", ranks),
                                   SimConfig(), jobs=jobs)
    t0 = time.perf_counter()
    sharded = sharded_sim.run(max_events=_SIM_MAX_EVENTS)
    sharded_s = time.perf_counter() - t0

    out: Dict[str, Any] = {
        "scenario": "mixed_ar_a2a",
        "nodes_per_rank": nodes_per_rank,
        "ranks": ranks,
        "total_nodes": total_nodes,
        "jobs": jobs,
        "cpu_count": os.cpu_count(),
        "single": {"wall_s": round(single_s, 4),
                   "events": single.events,
                   "events_per_sec": round(single.events / single_s, 1)},
        "sharded": {"wall_s": round(sharded_s, 4),
                    "events": sharded.events,
                    "events_per_sec": round(sharded.events / sharded_s, 1),
                    "grants": sharded_sim.stats.get("grants"),
                    "injections": sharded_sim.stats.get("injections"),
                    "worker_batches":
                        sharded_sim.stats.get("worker_batches")},
        "speedup": round(single_s / sharded_s, 3),
        "bit_identical": _same_result(sharded, single),
    }

    world = cfg["fleet_world"]
    src = SynthSource(profile=get_scenario("serve-decode-burst").profile(),
                      world_size=world, steps=cfg["fleet_steps"],
                      ops_per_step=cfg["fleet_ops"], seed=0)
    fab = Fabric.build("switch", world, materialize_graph=False)
    fleet_sim = ShardedSimulator(src, fab, SimConfig(),
                                 jobs=cfg["fleet_jobs"])
    t0 = time.perf_counter()
    fres = fleet_sim.run(max_events=_SIM_MAX_EVENTS)
    fleet_s = time.perf_counter() - t0
    out["fleet"] = {
        "scenario": "serve-decode-burst",
        "world_size": world,
        "jobs": cfg["fleet_jobs"],
        "wall_s": round(fleet_s, 2),
        "events": fres.events,
        "events_per_sec": round(fres.events / fleet_s, 1),
        "makespan_s": fres.makespan_s,
        "completed": not fres.aborted,
        "grants": fleet_sim.stats.get("grants"),
    }
    return out


# -------------------------------------------------------------------- serve
def perf_serve(scale: str = "full", **_: Any) -> Dict[str, Any]:
    """Live benchmark service: submit-to-report latency + scrape throughput.

    One in-process daemon on an ephemeral port, driven over real HTTP.
    ``cold`` is the end-to-end submission latency (POST the spec, poll to
    completion, fetch the report bytes) with ``/metrics`` scraped
    continuously while the sweep runs — the scrape path must never block
    the sweep.  ``scrape`` then prices the merged exposition alone
    (service registry + per-job sweep registries under ``job=`` labels).
    ``cached`` resubmits the identical spec: the content-addressed cache
    must answer with zero new simulations (``cached_executed`` is the
    absolute contract), making the replay latency the service's floor.
    """
    import tempfile
    import urllib.request

    from ..serve_api import BenchmarkService

    cfg = _cfg(scale)["serve"]
    spec = {
        "name": "perf-serve",
        "workloads": [{"pattern": "moe_mixed",
                       "args": {"mode": "mixed", "iters": cfg["iters"]}}],
        "axes": {"topology": ["ring", "switch", "clos"],
                 "world_size": cfg["world_sizes"],
                 "fidelity": cfg["fidelities"]},
    }
    payload = json.dumps(spec).encode()

    def post() -> str:
        req = urllib.request.Request(
            f"{base}/api/v1/sweeps", data=payload, method="POST")
        with urllib.request.urlopen(req) as r:
            return json.loads(r.read())["id"]

    def get(path: str) -> bytes:
        with urllib.request.urlopen(base + path) as r:
            return r.read()

    def submit_to_report(scrape_while_running: bool
                         ) -> Tuple[float, str, bytes, int]:
        t0 = time.perf_counter()
        jid = post()
        while True:
            st = json.loads(get(f"/api/v1/sweeps/{jid}"))
            if st["state"] in ("done", "failed"):
                break
            if scrape_while_running:
                get("/metrics")
        if st["state"] != "done":
            raise RuntimeError(f"perf_serve sweep failed: {st['error']}")
        rep = get(f"/api/v1/sweeps/{jid}/report")
        return (time.perf_counter() - t0, jid, rep,
                st["progress"]["cached"])

    with tempfile.TemporaryDirectory() as tmp:
        svc = BenchmarkService(
            port=0, state_dir=os.path.join(tmp, "state"),
            cache_dir=os.path.join(tmp, "cache"), workers=1, quiet=True)
        host, port = svc.start()
        base = f"http://{host}:{port}"
        try:
            cold_s, jid, report_bytes, _ = submit_to_report(True)
            n_cfgs = len(json.loads(report_bytes)["workloads"]
                         ["moe_mixed-mixed"]["ranking"])

            n = cfg["scrapes"]
            t0 = time.perf_counter()
            for _i in range(n):
                body = get("/metrics")
            scrape_s = time.perf_counter() - t0

            warm_s, _jid2, _rep2, cached = submit_to_report(False)
        finally:
            svc.stop(drain=True, timeout_s=60)

    return {
        "configs": n_cfgs,
        "cold": {
            "wall_s": round(cold_s, 4),
            "runs_per_sec": round(n_cfgs / cold_s, 1),
            "report_bytes": len(report_bytes),
        },
        "cached": {
            "wall_s": round(warm_s, 4),
            "runs_per_sec": round(n_cfgs / warm_s, 1),
            # must equal configs: the replay performed zero simulations
            "cached_runs": cached,
            "speedup": round(cold_s / warm_s, 2),
        },
        "scrape": {
            "n": n,
            "wall_s": round(scrape_s, 4),
            "scrapes_per_sec": round(n / scrape_s, 1),
            "exposition_bytes": len(body),
        },
    }


# ------------------------------------------------------------------- driver
BENCHMARKS = {
    "perf_feeder": perf_feeder,
    "perf_sim": perf_sim,
    "perf_netmodel": perf_netmodel,
    "perf_chkb": perf_chkb,
    "perf_synth": perf_synth,
    "perf_explore": perf_explore,
    "perf_ingest": perf_ingest,
    "perf_faults": perf_faults,
    "perf_obs": perf_obs,
    "perf_shard": perf_shard,
    "perf_serve": perf_serve,
}


def run_suite(scale: str = "full", baseline: bool = True,
              names: Optional[Sequence[str]] = None) -> Dict[str, Any]:
    """Run the (subset of the) perf suite; returns the BENCH document.

    Benchmarks are resolved through the stage registry (kind="benchmark"),
    the same dispatch path as ``python -m repro bench`` and the paper-figure
    harness, so both entry points produce an identically-shaped document.
    """
    from ..pipeline.registry import get_stage

    _cfg(scale)  # validate early
    selected = list(names) if names else list(BENCHMARKS)
    for name in selected:
        if name not in BENCHMARKS:
            raise ValueError(f"unknown perf benchmark {name!r}; "
                             f"options: {sorted(BENCHMARKS)}")
    doc: Dict[str, Any] = {
        "schema": "repro-bench-perf/v1",
        "created_unix": int(time.time()),
        "scale": scale,
        "host": {
            "python": sys.version.split()[0],
            "platform": platform.platform(),
            # perf_shard's wall-clock rates only transfer between hosts
            # with the same core count; the gate checks this field
            "cpu_count": os.cpu_count(),
            "jobs": {"shard": _SCALE[scale]["shard"]["jobs"],
                     "fleet": _SCALE[scale]["shard"]["fleet_jobs"],
                     "explore": _SCALE[scale]["explore"]["jobs"]},
        },
    }
    for name in selected:
        fn = get_stage("benchmark", name)
        t0 = time.perf_counter()
        doc[name] = fn(scale=scale, baseline=baseline)
        doc[name]["bench_wall_s"] = round(time.perf_counter() - t0, 2)
    return doc


def write_bench(doc: Dict[str, Any], path: str = "BENCH_perf.json") -> str:
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=False)
        fh.write("\n")
    return path


# ---------------------------------------------------------------- perf gate
def gate_regressions(current: Dict[str, Any], baseline: Dict[str, Any],
                     threshold: float = 0.2) -> Tuple[List[str], List[str]]:
    """Compare a fresh bench document against the committed baseline.

    Only rows present in BOTH documents are compared (a smoke-scale run
    gates against the matching subset of the full-scale baseline).  A row
    regresses when its events/sec (sim) or nodes/sec (feeder) falls more
    than ``threshold`` below the baseline.  Returns (failures, report
    lines); an empty failure list means the gate passes.
    """
    failures: List[str] = []
    report: List[str] = []

    def check(label: str, cur: float, base: float) -> None:
        if base <= 0:
            return
        ratio = cur / base
        line = (f"{label}: {cur:,.0f} vs baseline {base:,.0f} "
                f"({ratio:.2f}x)")
        report.append(line)
        if ratio < 1.0 - threshold:
            failures.append(line)

    base_feeder = {(r["nodes"], r["window"]): r for r in
                   baseline.get("perf_feeder", {}).get("drain", [])}
    for r in current.get("perf_feeder", {}).get("drain", []):
        b = base_feeder.get((r["nodes"], r["window"]))
        if b:
            check(f"perf_feeder nodes={r['nodes']} window={r['window']} "
                  f"nodes/sec", r["nodes_per_sec"], b["nodes_per_sec"])

    base_sim = {(r["scenario"], r["nodes_per_rank"], r["ranks"]): r for r in
                baseline.get("perf_sim", {}).get("scenarios", [])}
    for r in current.get("perf_sim", {}).get("scenarios", []):
        b = base_sim.get((r["scenario"], r["nodes_per_rank"], r["ranks"]))
        if b:
            check(f"perf_sim {r['scenario']} {r['nodes_per_rank']}x"
                  f"{r['ranks']} events/sec",
                  r["engine"]["events_per_sec"],
                  b["engine"]["events_per_sec"])

    # explore engine: expansion rate always comparable; the cached-replay
    # rate only when the sweep grids match (configs and jobs agree)
    cur_x = current.get("perf_explore", {})
    base_x = baseline.get("perf_explore", {})
    if "expand" in cur_x and "expand" in base_x:
        check("perf_explore expand configs/sec",
              cur_x["expand"]["configs_per_sec"],
              base_x["expand"]["configs_per_sec"])
    cs, bs = cur_x.get("sweep", {}), base_x.get("sweep", {})
    if cs and bs and (cs["configs"], cs["jobs"]) == (bs["configs"],
                                                     bs["jobs"]):
        check(f"perf_explore cached sweep {cs['configs']} configs runs/sec",
              cs["cached_runs_per_sec"], bs["cached_runs_per_sec"])

    # faults: the empty-plan overhead ratio is an absolute contract (the
    # fault-free path pays nothing), gated against 1.05 — no baseline needed
    cur_f = current.get("perf_faults", {})
    if "empty_plan_overhead" in cur_f:
        overhead = cur_f["empty_plan_overhead"]
        line = f"perf_faults empty_plan_overhead: {overhead:.3f}x (max 1.05)"
        report.append(line)
        if overhead > 1.05:
            failures.append(line)
        if not cur_f.get("empty_plan_bit_identical", True):
            failures.append("perf_faults: empty plan broke bit-identity "
                            "with the fault-free run")
    base_f = baseline.get("perf_faults", {})
    cr, br = cur_f.get("runs", {}), base_f.get("runs", {})
    if ("no_plan" in cr and "no_plan" in br
            and (cur_f.get("nodes_per_rank"), cur_f.get("ranks"))
            == (base_f.get("nodes_per_rank"), base_f.get("ranks"))):
        for label in ("no_plan", "chaos_plan"):
            if label in cr and label in br:
                check(f"perf_faults {label} events/sec",
                      cr[label]["events_per_sec"],
                      br[label]["events_per_sec"])

    # obs: the overhead ratios are absolute contracts (recording is cheap,
    # off is free) — no baseline needed; the off events/sec additionally
    # gates against the baseline like any other engine rate
    cur_o = current.get("perf_obs", {})
    for key, cap in (("timeline_overhead", 1.15),
                     ("metrics_overhead", 1.15)):
        if key in cur_o:
            line = f"perf_obs {key}: {cur_o[key]:.3f}x (max {cap})"
            report.append(line)
            if cur_o[key] > cap:
                failures.append(line)
    if cur_o and not cur_o.get("instrumented_identical", True):
        failures.append("perf_obs: instrumented run broke bit-identity "
                        "with the uninstrumented run")
    base_o = baseline.get("perf_obs", {})
    co, bo = cur_o.get("runs", {}), base_o.get("runs", {})
    if ("off" in co and "off" in bo
            and (cur_o.get("nodes_per_rank"), cur_o.get("ranks"))
            == (base_o.get("nodes_per_rank"), base_o.get("ranks"))):
        check("perf_obs off events/sec",
              co["off"]["events_per_sec"], bo["off"]["events_per_sec"])

    # ingestion: events/sec is scale-independent (streaming, O(events)), so
    # a smoke run gates directly against the full-scale baseline rates
    cur_i = current.get("perf_ingest", {})
    base_i = baseline.get("perf_ingest", {})
    for stage in ("parse", "standardize", "end_to_end"):
        if stage in cur_i and stage in base_i:
            check(f"perf_ingest {stage} events/sec",
                  cur_i[stage]["events_per_sec"],
                  base_i[stage]["events_per_sec"])

    # shard: bit-identity and fleet completion are absolute contracts; the
    # wall-clock rates gate against the baseline only when the grid and
    # worker counts match (scripts/perf_gate.py additionally warns and
    # skips this whole section when the baseline host's core count differs
    # from the current host's — an 8-worker rate from a 32-core box is not
    # a contract a 1-core CI runner can honor)
    cur_s = current.get("perf_shard", {})
    base_s = baseline.get("perf_shard", {})
    if cur_s:
        ident = cur_s.get("bit_identical", True)
        line = f"perf_shard bit_identical: {ident}"
        report.append(line)
        if not ident:
            failures.append("perf_shard: sharded run broke bit-identity "
                            "with the single-process engine")
        fleet = cur_s.get("fleet", {})
        if fleet and not fleet.get("completed", True):
            failures.append(
                f"perf_shard: fleet scenario world={fleet.get('world_size')}"
                " did not complete")
    if (cur_s.get("sharded") and base_s.get("sharded")
            and (cur_s.get("nodes_per_rank"), cur_s.get("ranks"),
                 cur_s.get("jobs"))
            == (base_s.get("nodes_per_rank"), base_s.get("ranks"),
                base_s.get("jobs"))):
        check(f"perf_shard sharded {cur_s['nodes_per_rank']}x"
              f"{cur_s['ranks']} jobs={cur_s['jobs']} events/sec",
              cur_s["sharded"]["events_per_sec"],
              base_s["sharded"]["events_per_sec"])
    cf, bf = cur_s.get("fleet", {}), base_s.get("fleet", {})
    if (cf.get("events_per_sec") and bf.get("events_per_sec")
            and (cf.get("world_size"), cf.get("jobs"))
            == (bf.get("world_size"), bf.get("jobs"))):
        check(f"perf_shard fleet world={cf['world_size']} events/sec",
              cf["events_per_sec"], bf["events_per_sec"])
    # serve: the cached replay answering with zero new simulations is an
    # absolute contract; scrape throughput gates against the baseline and
    # the cached submit-to-report rate gates when the sweep grids match
    cur_v = current.get("perf_serve", {})
    base_v = baseline.get("perf_serve", {})
    if cur_v:
        cached = cur_v.get("cached", {})
        if cached and cached.get("cached_runs") != cur_v.get("configs"):
            failures.append(
                "perf_serve: cached resubmission was not fully "
                f"cache-served ({cached.get('cached_runs')}/"
                f"{cur_v.get('configs')} rows cached)")
    if "scrape" in cur_v and "scrape" in base_v:
        check("perf_serve /metrics scrapes/sec",
              cur_v["scrape"]["scrapes_per_sec"],
              base_v["scrape"]["scrapes_per_sec"])
    if (cur_v.get("configs") == base_v.get("configs")
            and "cached" in cur_v and "cached" in base_v):
        check(f"perf_serve cached submit-to-report "
              f"{cur_v['configs']} configs runs/sec",
              cur_v["cached"]["runs_per_sec"],
              base_v["cached"]["runs_per_sec"])
    return failures, report


# ------------------------------------------------------------ bench compare
def _rate_rows(doc: Dict[str, Any]) -> Dict[str, float]:
    """Flatten a bench document into ``label -> throughput`` rows.

    Every benchmark's headline rate metric (events/sec, nodes/sec,
    configs/sec, ...) under a stable label, so two documents can be joined
    row-by-row regardless of which benchmarks each one ran."""
    rows: Dict[str, float] = {}
    for r in doc.get("perf_feeder", {}).get("drain", []):
        rows[f"feeder drain nodes={r['nodes']} window={r['window']} "
             "nodes/sec"] = r["nodes_per_sec"]
    for r in doc.get("perf_sim", {}).get("scenarios", []):
        rows[f"sim {r['scenario']} {r['nodes_per_rank']}x{r['ranks']} "
             "events/sec"] = r["engine"]["events_per_sec"]
    for r in doc.get("perf_netmodel", {}).get("scenarios", []):
        for mode in ("analytic", "link"):
            if mode in r:
                rows[f"netmodel {mode} {r['nodes_per_rank']}x{r['ranks']} "
                     "events/sec"] = r[mode]["events_per_sec"]
    ch = doc.get("perf_chkb", {})
    for section in ("encode", "decode", "file"):
        for r in ch.get(section, []):
            rows[f"chkb {section} {r['path']} nodes/sec"] = r["nodes_per_sec"]
    sy = doc.get("perf_synth", {})
    for section in ("profile", "generate"):
        if "nodes_per_sec" in sy.get(section, {}):
            rows[f"synth {section} nodes/sec"] = sy[section]["nodes_per_sec"]
    ex = doc.get("perf_explore", {})
    if "configs_per_sec" in ex.get("expand", {}):
        rows["explore expand configs/sec"] = ex["expand"]["configs_per_sec"]
    for key in ("cold_runs_per_sec", "cached_runs_per_sec"):
        if key in ex.get("sweep", {}):
            rows[f"explore sweep {key.split('_')[0]} runs/sec"] = \
                ex["sweep"][key]
    ing = doc.get("perf_ingest", {})
    for stage in ("parse", "standardize", "end_to_end"):
        if "events_per_sec" in ing.get(stage, {}):
            rows[f"ingest {stage} events/sec"] = \
                ing[stage]["events_per_sec"]
    for name in ("perf_faults", "perf_obs"):
        for label, r in doc.get(name, {}).get("runs", {}).items():
            if "events_per_sec" in r:
                rows[f"{name.split('_')[1]} {label} events/sec"] = \
                    r["events_per_sec"]
    sh = doc.get("perf_shard", {})
    for label in ("single", "sharded"):
        if "events_per_sec" in sh.get(label, {}):
            rows[f"shard {label} {sh.get('nodes_per_rank')}x"
                 f"{sh.get('ranks')} events/sec"] = \
                sh[label]["events_per_sec"]
    if "events_per_sec" in sh.get("fleet", {}):
        rows[f"shard fleet world={sh['fleet'].get('world_size')} "
             "events/sec"] = sh["fleet"]["events_per_sec"]
    sv = doc.get("perf_serve", {})
    if "scrapes_per_sec" in sv.get("scrape", {}):
        rows["serve /metrics scrapes/sec"] = sv["scrape"]["scrapes_per_sec"]
    for label in ("cold", "cached"):
        if "runs_per_sec" in sv.get(label, {}):
            rows[f"serve {label} submit-to-report runs/sec"] = \
                sv[label]["runs_per_sec"]
    return rows


def compare_bench(old_doc: Dict[str, Any], new_doc: Dict[str, Any],
                  old_label: str = "old", new_label: str = "new") -> str:
    """Per-benchmark throughput delta table between two bench documents.

    Backs ``repro bench --compare OLD.json NEW.json``.  Rows present in
    only one document render with a ``-`` on the missing side and no
    delta; the delta column is ``new/old - 1`` (positive = faster)."""
    old_rows = _rate_rows(old_doc)
    new_rows = _rate_rows(new_doc)
    labels = list(old_rows)
    labels += [k for k in new_rows if k not in old_rows]
    width = max([len(l) for l in labels] + [len("benchmark")])
    ow = max(len(old_label), 12)
    nw = max(len(new_label), 12)
    lines = [
        f"{'benchmark':<{width}}  {old_label:>{ow}}  {new_label:>{nw}}  "
        f"{'delta':>7}",
        f"{'-' * width}  {'-' * ow}  {'-' * nw}  {'-' * 7}",
    ]
    for label in labels:
        o, n = old_rows.get(label), new_rows.get(label)
        os_ = f"{o:,.0f}" if o is not None else "-"
        ns_ = f"{n:,.0f}" if n is not None else "-"
        if o and n:
            delta = f"{n / o - 1.0:+.1%}"
        else:
            delta = "-"
        lines.append(f"{label:<{width}}  {os_:>{ow}}  {ns_:>{nw}}  "
                     f"{delta:>7}")
    scales = (old_doc.get("scale"), new_doc.get("scale"))
    if scales[0] != scales[1]:
        lines.append(f"note: scales differ ({old_label}={scales[0]}, "
                     f"{new_label}={scales[1]}); only matching grids are "
                     "meaningful")
    return "\n".join(lines)
