"""Hot-path microbenchmark suite: the repo's performance trajectory.

Three benchmarks, registered in the stage registry under kind="benchmark"
(:mod:`repro.perf` registers them on import) and dispatched by both
``python -m repro bench`` and ``python -m benchmarks.perf.run``:

* ``perf_feeder`` — dependency-aware drain throughput (nodes/sec) across
  trace sizes and window sizes; exercises the O(1) ``in_flight`` counter and
  bounded bookkeeping inside the elastic-refill loop.
* ``perf_sim``    — simulator events/sec on the mixed AR×A2A scenario
  (paper §5.3) across trace sizes and rank counts, optionally against the
  frozen pre-optimization engine (``repro.sim.ReferenceSimulator``) so the
  speedup columns are measured, not asserted.
* ``perf_chkb``   — CHKB encode / decode throughput (MB/s and nodes/s),
  v3 row blocks vs v4 columnar blocks, including the column-level decode
  path (``NodeColumns`` — no ETNode materialization) and the real columnar
  consumer (:func:`repro.core.analysis.columnar_summary`).

Results aggregate into a JSON document written to ``BENCH_perf.json`` at the
repo root (see :func:`run_suite` / :func:`write_bench`).  Wall-clock numbers
are machine-dependent; the ``*_speedup`` ratios are the stable signal.
"""
from __future__ import annotations

import json
import platform
import sys
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..core import generator
from ..core.feeder import ETFeeder
from ..core.schema import ExecutionTrace
from ..core.serialization import (_decode_block_v3, _decode_block_v4,
                                  _decode_block_v4_columns, _encode_block_v3,
                                  _encode_block_v4)

SCALES = ("smoke", "full")

#: per-scale knobs: (feeder trace sizes, sim (nodes_per_rank, ranks) grid,
#: sim baseline subset, chkb trace size)
_SCALE = {
    "smoke": {
        "feeder_nodes": [10_000],
        "sim_grid": [(1_000, 4), (1_000, 8)],
        "sim_baseline": [(1_000, 8)],
        "chkb_nodes": 10_000,
        "chkb_repeat": 3,
    },
    "full": {
        "feeder_nodes": [10_000, 100_000],
        "sim_grid": [(1_000, 4), (1_000, 8), (1_000, 16),
                     (10_000, 4), (10_000, 8), (10_000, 16),
                     (100_000, 8)],
        "sim_baseline": [(1_000, 8), (10_000, 8), (100_000, 8)],
        "chkb_nodes": 50_000,
        "chkb_repeat": 5,
    },
}

_SIM_MAX_EVENTS = 200_000_000


def _cfg(scale: str) -> Dict[str, Any]:
    if scale not in _SCALE:
        raise ValueError(f"unknown scale {scale!r}; options: {SCALES}")
    return _SCALE[scale]


def _mixed_trace(nodes: int, ranks: int, rank: int = 0) -> ExecutionTrace:
    """§5.3 mixed AR×A2A MoE trace sized to ~``nodes`` nodes."""
    per_iter = 5                       # moe_mixed emits ~5 nodes per iteration
    iters = max(1, nodes // per_iter)
    return generator.moe_mixed_collectives(iters=iters, ranks=ranks,
                                           rank=rank, jitter=True)


def _chain_heavy_trace(nodes: int) -> ExecutionTrace:
    """Single-rank DP-style trace (deep chains + fan-in) for feeder drains."""
    layers = 8
    steps = max(1, nodes // (2 * layers + 1))
    return generator.dp_allreduce_pattern(steps=steps, layers=layers, ranks=8)


# ------------------------------------------------------------------- feeder
def perf_feeder(scale: str = "full", **_: Any) -> Dict[str, Any]:
    """Feeder drain throughput (nodes/sec) across trace and window sizes."""
    rows: List[Dict[str, Any]] = []
    for nodes in _cfg(scale)["feeder_nodes"]:
        et = _chain_heavy_trace(nodes)
        for window in (64, 1024):
            feeder = ETFeeder(et, window=window, policy="fifo")
            t0 = time.perf_counter()
            order = feeder.drain_order()
            dt = time.perf_counter() - t0
            rows.append({
                "nodes": len(order),
                "window": window,
                "wall_s": round(dt, 6),
                "nodes_per_sec": round(len(order) / dt, 1),
            })
    return {"drain": rows}


# ---------------------------------------------------------------- simulator
def _run_sim(engine_cls, traces, ranks: int) -> Dict[str, Any]:
    from ..sim import Fabric
    fabric = Fabric.build("switch", ranks)
    t0 = time.perf_counter()
    res = engine_cls(traces, fabric).run(max_events=_SIM_MAX_EVENTS)
    dt = time.perf_counter() - t0
    return {
        "wall_s": round(dt, 4),
        "events": res.events,
        "events_per_sec": round(res.events / dt, 1),
        "makespan_s": res.makespan_s,
        "flows": len(res.flows),
    }


def perf_sim(scale: str = "full", baseline: bool = True,
             **_: Any) -> Dict[str, Any]:
    """Simulator throughput on mixed AR×A2A scenarios; optional reference
    (pre-optimization) baseline for measured speedups."""
    from ..sim import ReferenceSimulator, Simulator
    cfg = _cfg(scale)
    baseline_grid = set(cfg["sim_baseline"]) if baseline else set()
    rows: List[Dict[str, Any]] = []
    for nodes_per_rank, ranks in cfg["sim_grid"]:
        traces = [_mixed_trace(nodes_per_rank, ranks, rank=r)
                  for r in range(ranks)]
        total = sum(len(t) for t in traces)
        row: Dict[str, Any] = {
            "scenario": "mixed_ar_a2a",
            "nodes_per_rank": nodes_per_rank,
            "ranks": ranks,
            "total_nodes": total,
            "engine": _run_sim(Simulator, traces, ranks),
        }
        if (nodes_per_rank, ranks) in baseline_grid:
            ref = _run_sim(ReferenceSimulator, traces, ranks)
            row["baseline"] = ref
            row["wall_speedup"] = round(
                ref["wall_s"] / row["engine"]["wall_s"], 2)
            row["events_per_sec_speedup"] = round(
                row["engine"]["events_per_sec"] / ref["events_per_sec"], 2)
        rows.append(row)
    return {"scenarios": rows}


# --------------------------------------------------------------------- chkb
def _time_it(fn, *args, repeat: int = 3) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best


def perf_chkb(scale: str = "full", **_: Any) -> Dict[str, Any]:
    """CHKB v3 vs v4 block encode/decode throughput (MB/s, nodes/s)."""
    cfg = _cfg(scale)
    repeat = cfg["chkb_repeat"]
    et = _chain_heavy_trace(cfg["chkb_nodes"])
    nodes = et.sorted_nodes()
    n = len(nodes)
    b3 = _encode_block_v3(nodes)
    b4 = _encode_block_v4(nodes)

    def row(label: str, seconds: float, payload: int) -> Dict[str, Any]:
        return {"path": label, "wall_s": round(seconds, 5),
                "mb_per_sec": round(payload / seconds / 1e6, 2),
                "nodes_per_sec": round(n / seconds, 1)}

    enc3 = _time_it(_encode_block_v3, nodes, repeat=repeat)
    enc4 = _time_it(_encode_block_v4, nodes, repeat=repeat)
    dec3 = _time_it(_decode_block_v3, b3, repeat=repeat)
    dec4_nodes = _time_it(_decode_block_v4, b4, repeat=repeat)
    dec4_cols = _time_it(_decode_block_v4_columns, b4, repeat=repeat)

    # end-to-end file paths (compressed, default codec) + the columnar
    # consumer vs the SAME numeric summary over materialized nodes of the
    # SAME v4 file — isolating columns-vs-objects, not codec or workload
    import os
    import tempfile
    from ..core.analysis import columnar_summary
    from ..core.serialization import ChkbReader, load, save

    def node_summary(path: str) -> None:
        """columnar_summary's numeric workload via full node objects."""
        with ChkbReader(path) as r:
            edges = total_bytes = 0
            duration = 0.0
            for node in r.iter_nodes():
                edges += (len(node.ctrl_deps) + len(node.data_deps)
                          + len(node.sync_deps))
                total_bytes += node.comm_bytes
                duration += node.duration_micros

    with tempfile.TemporaryDirectory() as tmp:
        p3 = os.path.join(tmp, "t3.chkb")
        p4 = os.path.join(tmp, "t4.chkb")
        save(et, p3, version=3)
        save(et, p4, version=4)
        size3 = os.path.getsize(p3)
        size4 = os.path.getsize(p4)
        load3 = _time_it(load, p3, repeat=repeat)
        load4 = _time_it(load, p4, repeat=repeat)
        summary_cols = _time_it(columnar_summary, p4, repeat=repeat)
        summary_nodes = _time_it(node_summary, p4, repeat=repeat)

    def frow(label: str, seconds: float, file_bytes: int) -> Dict[str, Any]:
        return {"path": label, "wall_s": round(seconds, 5),
                "file_mb_per_sec": round(file_bytes / seconds / 1e6, 2),
                "nodes_per_sec": round(n / seconds, 1)}

    return {
        "block_nodes": n,
        "block_bytes": {"v3": len(b3), "v4": len(b4)},
        "file_bytes": {"v3": size3, "v4": size4},
        "encode": [row("v3_rows", enc3, len(b3)),
                   row("v4_columnar", enc4, len(b4))],
        "decode": [row("v3_rows_to_nodes", dec3, len(b3)),
                   row("v4_columnar_to_nodes", dec4_nodes, len(b4)),
                   row("v4_columnar_to_columns", dec4_cols, len(b4))],
        "file": [frow("load_v3", load3, size3),
                 frow("load_v4", load4, size4),
                 frow("columnar_summary_v4", summary_cols, size4),
                 frow("node_summary_v4", summary_nodes, size4)],
        "encode_speedup": round(enc3 / enc4, 2),
        # headline: block decode to the format's usable in-memory structure.
        # v4's structure IS the columns (NodeColumns) — object
        # materialization is optional and measured separately above.
        "block_decode_speedup": round(dec3 / dec4_cols, 2),
        "node_decode_speedup": round(dec3 / dec4_nodes, 2),
        # same file, same numeric summary: columns vs node objects
        "columnar_summary_speedup": round(summary_nodes / summary_cols, 2),
    }


# ------------------------------------------------------------------- driver
BENCHMARKS = {
    "perf_feeder": perf_feeder,
    "perf_sim": perf_sim,
    "perf_chkb": perf_chkb,
}


def run_suite(scale: str = "full", baseline: bool = True,
              names: Optional[Sequence[str]] = None) -> Dict[str, Any]:
    """Run the (subset of the) perf suite; returns the BENCH document.

    Benchmarks are resolved through the stage registry (kind="benchmark"),
    the same dispatch path as ``python -m repro bench`` and the paper-figure
    harness, so both entry points produce an identically-shaped document.
    """
    from ..pipeline.registry import get_stage

    _cfg(scale)  # validate early
    selected = list(names) if names else list(BENCHMARKS)
    for name in selected:
        if name not in BENCHMARKS:
            raise ValueError(f"unknown perf benchmark {name!r}; "
                             f"options: {sorted(BENCHMARKS)}")
    doc: Dict[str, Any] = {
        "schema": "repro-bench-perf/v1",
        "created_unix": int(time.time()),
        "scale": scale,
        "host": {
            "python": sys.version.split()[0],
            "platform": platform.platform(),
        },
    }
    for name in selected:
        fn = get_stage("benchmark", name)
        t0 = time.perf_counter()
        doc[name] = fn(scale=scale, baseline=baseline)
        doc[name]["bench_wall_s"] = round(time.perf_counter() - t0, 2)
    return doc


def write_bench(doc: Dict[str, Any], path: str = "BENCH_perf.json") -> str:
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=False)
        fh.write("\n")
    return path
