"""``python -m repro`` — the pipeline CLI."""
from .cli import main

if __name__ == "__main__":
    raise SystemExit(main())
