"""Logical-axis sharding rules -> PartitionSpec plans.

Model code annotates tensors with *logical* axis names (``shard(x, "batch",
"seq", "embed")``); a rules table maps logical names to mesh axes.  This is
the MaxText/flax-linen "logical axis" pattern, reduced to one small module.

Robustness rule: a logical->mesh mapping is applied only when the tensor
dimension is divisible by the mesh-axis size, otherwise that dim is left
replicated.  This one rule cleanly handles every awkward case in the assigned
pool (hymba's 25 heads, GQA kv=8/5/2 vs a 16-way model axis, batch=1 decode)
without per-arch special cases — and the *dropped* shardings are exactly the
hillclimbing targets that §Perf iterates on (e.g. padded-heads TP).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Iterator, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisVal = Union[None, str, Tuple[str, ...]]

# ---------------------------------------------------------------- rule table

# Default logical-axis rules for the production mesh (("pod",) "data", "model").
# "batch" shards over data-like axes; everything weight/feature-like shards
# over "model"; "kv_seq" (the decode KV-cache sequence dim) shards over
# "model" — the distributed flash-decode design (partial-softmax combine is
# expressed through XLA's handling of reductions over sharded dims).
def default_rules(multi_pod: bool = False) -> Dict[str, AxisVal]:
    batch: AxisVal = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": batch,
        "seq": "model",          # sequence-parallel residual stream (SP)
        "embed": None,
        "heads": "model",        # q heads (TP) — auto-dropped if not divisible
        "kv_heads": "model",
        "head_dim": None,
        "qkv": "model",          # fused q/kv feature dim
        "ff": "model",           # MLP hidden (TP)
        "vocab": "model",
        "experts": "model",      # (virtual-)expert dim for MoE dispatch
        "capacity": None,
        "kv_seq": "model",       # decode KV cache sequence shards over model
        "ssm_inner": "model",    # Mamba inner dim
        "state": None,
        "frames": None,
    }


def fsdp_rules(multi_pod: bool = False) -> Dict[str, AxisVal]:
    """FSDP/ZeRO-3-flavored rules (§Perf experiment): the batch shards over
    EVERY mesh axis (per-device batch 1 at 256 chips), weights stay sharded
    over "model" (all-gathered at use, reduce-scattered in backward by
    GSPMD), and no tensor-parallel activation collectives exist at all.
    Trades per-layer activation all-reduces (O(B*S*D) each) for per-layer
    weight gathers (O(params/L)) — the right trade below the TP threshold."""
    batch: AxisVal = ("pod", "data", "model") if multi_pod \
        else ("data", "model")
    return {
        "batch": batch,
        "seq": None,
        "embed": None,
        "heads": None,            # batch already owns "model"
        "kv_heads": None,
        "head_dim": None,
        "qkv": "model",           # weight shards (gathered at use)
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "capacity": None,
        "kv_seq": "model",
        "ssm_inner": "model",
        "state": None,
        "frames": None,
    }


RULE_SETS = {"default": default_rules, "fsdp": fsdp_rules}


class _Ctx(threading.local):
    def __init__(self) -> None:
        self.rules: Optional[Dict[str, AxisVal]] = None
        self.mesh: Optional[Mesh] = None


_CTX = _Ctx()


@contextmanager
def use_rules(rules: Dict[str, AxisVal], mesh: Optional[Mesh] = None) -> Iterator[None]:
    """Activate logical->mesh rules (and optionally a mesh) for model code."""
    prev = (_CTX.rules, _CTX.mesh)
    _CTX.rules = rules
    _CTX.mesh = mesh or (jax.sharding.get_abstract_mesh()
                         if hasattr(jax.sharding, "get_abstract_mesh") else None)
    try:
        yield
    finally:
        _CTX.rules, _CTX.mesh = prev


def active_mesh() -> Optional[Mesh]:
    if _CTX.mesh is not None:
        return _CTX.mesh
    try:
        m = jax.sharding.get_abstract_mesh()  # inside jit with use_mesh
        if m is not None and m.shape:
            return m
    except Exception:
        pass
    return None


def _axis_size(mesh: Mesh, axis: AxisVal) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return int(mesh.shape[axis])
    n = 1
    for a in axis:
        n *= int(mesh.shape[a])
    return n


def spec_for(shape: Sequence[int], logical: Sequence[Optional[str]],
             rules: Optional[Dict[str, AxisVal]] = None,
             mesh: Optional[Mesh] = None) -> P:
    """PartitionSpec for `shape` under the active rules, with the
    divisibility guard (non-divisible dims fall back to replicated)."""
    rules = rules if rules is not None else (_CTX.rules or {})
    mesh = mesh if mesh is not None else _CTX.mesh
    used: set = set()
    out = []
    for dim, name in zip(shape, logical):
        ax = rules.get(name) if name else None
        if ax is None:
            out.append(None)
            continue
        key = ax if isinstance(ax, str) else tuple(ax)
        if key in used or (isinstance(key, tuple) and any(a in used for a in key)):
            out.append(None)        # a mesh axis may appear once per spec
            continue
        if mesh is not None:
            sz = _axis_size(mesh, ax)
            if sz <= 1 or int(dim) % sz != 0:
                out.append(None)
                continue
        out.append(ax)
        if isinstance(key, tuple):
            used.update(key)
        else:
            used.add(key)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Apply a sharding constraint by logical axis names (no-op without rules
    or mesh, so reduced-config CPU smoke tests run the same code path)."""
    if _CTX.rules is None:
        return x
    mesh = active_mesh()
    if mesh is None:
        return x
    spec = spec_for(x.shape, logical, _CTX.rules, mesh)
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except ValueError:
        return jax.lax.with_sharding_constraint(x, spec)


def named_sharding(mesh: Mesh, shape: Sequence[int],
                   logical: Sequence[Optional[str]],
                   rules: Dict[str, AxisVal]) -> NamedSharding:
    return NamedSharding(mesh, spec_for(shape, logical, rules, mesh))


def tree_shardings(mesh: Mesh, spec_tree, logical_tree, rules: Dict[str, AxisVal]):
    """Map (ShapeDtypeStruct pytree, logical-axes pytree) -> NamedSharding tree."""
    # tree_map flattens following spec_tree's structure; the logical tree may
    # carry tuples at spec_tree's leaf positions (flatten_up_to semantics).
    return jax.tree.map(
        lambda sds, log: named_sharding(mesh, sds.shape, log, rules),
        spec_tree, logical_tree)
