"""GPipe-style pipeline parallelism via shard_map + collective_permute.

At multi-pod scale the "pod" axis can carry pipeline stages (cross-pod DCN
links are too slow for TP but fine for the once-per-microbatch boundary
activations of PP).  This module implements the classic GPipe schedule —
M microbatches streamed through S stages with (S-1) bubble slots — as a
pure-JAX function over a stage-sharded parameter stack.

The layer stack is viewed as [S, L/S, ...]; each device along the pipeline
axis owns one stage's params.  A shard_map program rotates microbatch
activations around the stage ring with ``lax.ppermute`` — the TPU-native
point-to-point (COMM_SEND/RECV in the Chakra trace).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_forward(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    mesh: Mesh,
    axis: str,
    n_microbatches: int,
):
    """Build a pipelined forward: (stage_params, x) -> y.

    GPipe skew schedule over S stages and M microbatches (M + S - 1 ticks):
    at tick t, stage s processes microbatch m = t - s; activations move
    stage s -> s+1 each tick via ``lax.ppermute`` (COMM_SEND/RECV in the
    Chakra trace).  Stage 0 injects microbatch t at tick t; the last stage
    accumulates outputs, which a final psum replicates (only the last stage
    holds non-zeros, so the psum is the identity broadcast).

    ``stage_params`` leaves lead with the stage dim (sharded over ``axis``);
    ``x``: [M, mb, ...] microbatched input (replicated).
    """
    n_stages = int(mesh.shape[axis])

    def local_fn(params, xs):
        # params arrive as [1(stage), L/S, ...]: strip the sharded dim
        params = jax.tree.map(lambda p: p[0], params)
        # xs: [M, mb, ...] replicated
        stage = lax.axis_index(axis)
        total_ticks = n_microbatches + n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        mb_shape = xs.shape[1:]

        def take(t):
            return lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, n_microbatches - 1), 0, keepdims=False)

        def tick(carry, t):
            cur, out = carry
            active = (t >= stage) & (t - stage < n_microbatches)
            y = stage_fn(params, cur)
            y = jnp.where(active, y, cur)
            m = jnp.clip(t - stage, 0, n_microbatches - 1)
            write = active & (stage == n_stages - 1)
            upd = lax.dynamic_update_index_in_dim(out, y, m, 0)
            out = jnp.where(write, upd, out)
            nxt = lax.ppermute(y, axis, perm)
            cur = jnp.where(stage == 0, take(t + 1), nxt)
            return (cur, out), None

        cur0 = jnp.where(stage == 0, take(0), jnp.zeros(mb_shape, xs.dtype))
        out0 = jnp.zeros_like(xs)
        (_, out), _ = lax.scan(tick, (cur0, out0),
                               jnp.arange(total_ticks, dtype=jnp.int32))
        # only the last stage holds results; psum == broadcast to all
        return lax.psum(jnp.where(stage == n_stages - 1, out,
                                  jnp.zeros_like(out)), axis)

    return shard_map(local_fn, mesh=mesh,
                     in_specs=(P(axis), P()), out_specs=P(),
                     check_rep=False)


def stage_stack(params_stacked: Any, n_stages: int) -> Any:
    """[L, ...] param stack -> [S, L/S, ...] stage-major view."""
    def f(x):
        L = x.shape[0]
        assert L % n_stages == 0
        return x.reshape(n_stages, L // n_stages, *x.shape[1:])
    return jax.tree.map(f, params_stacked)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    """GPipe bubble overhead = (S-1)/(M+S-1)."""
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
