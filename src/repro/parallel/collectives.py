"""Explicit collectives: int8 error-feedback gradient compression and
shard_map building blocks for the replay engine.

Gradient compression (the distributed-optimization trick recorded in the
ETs): gradients are quantized to int8 with a per-tensor scale before the
data-parallel all-reduce — 4x less DP traffic at f32, 2x at bf16 — and the
quantization error is fed back into the next step's gradient (error
feedback keeps SGD convergence; tested on the 100M example).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


# ------------------------------------------------- int8 error-feedback comp
def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(grads: Any, error: Optional[Any] = None
                   ) -> Tuple[Any, Any, Any]:
    """Quantize a gradient pytree with error feedback.

    Returns (quantized tree of (int8, scale), new error tree, bytes ratio).
    """
    g_leaves, treedef = jax.tree.flatten(grads)
    if error is None:
        e_leaves = [jnp.zeros(g.shape, jnp.float32) for g in g_leaves]
    else:
        e_leaves = treedef.flatten_up_to(error)
    q_leaves, new_e = [], []
    raw = comp = 0
    for g, e in zip(g_leaves, e_leaves):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        q_leaves.append((q, s))
        new_e.append(corrected - dequantize_int8(q, s))
        raw += g.size * g.dtype.itemsize
        comp += q.size
    return (jax.tree.unflatten(treedef, q_leaves),
            jax.tree.unflatten(treedef, new_e), comp / max(raw, 1))


def compressed_psum_grads(grads: Any, error: Any, axis_name: str) -> Tuple[Any, Any]:
    """int8-compressed data-parallel gradient all-reduce (inside shard_map).

    Quantize(g + e) -> psum(int8 as int32 accum) -> dequantize -> mean.
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        # shared scale across the group: int8 values quantized with
        # different per-rank scales cannot be summed meaningfully
        local_scale = jnp.maximum(jnp.max(jnp.abs(corrected)), 1e-12) / 127.0
        s = lax.pmax(local_scale, axis_name)
        q = jnp.clip(jnp.round(corrected / s), -127, 127).astype(jnp.int8)
        # accumulate in int32 to avoid overflow across the group
        total = lax.psum(q.astype(jnp.int32), axis_name)
        n = lax.psum(jnp.ones((), jnp.float32), axis_name)
        deq = total.astype(jnp.float32) * s / n
        new_e = corrected - q.astype(jnp.float32) * s
        return deq.astype(g.dtype), new_e

    pairs = jax.tree.map(one, grads, error)
    g_out = jax.tree.map(lambda p: p[0], pairs,
                         is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2)
    e_out = jax.tree.map(lambda p: p[1], pairs,
                         is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2)
    return g_out, e_out


# ------------------------------------------------------ replay collectives
def make_collective_fn(kind: str, mesh: Mesh, axis: str = "data"):
    """shard_map-wrapped collective used by the trace replayer (§4.2): takes
    the local shard, performs the real collective over ``axis``."""
    spec = P(axis)

    n_shards = int(mesh.shape[axis])

    def ar(x):
        return lax.psum(x, axis)

    def ag(x):
        return lax.all_gather(x.reshape(-1), axis, tiled=True)

    def rs(x):
        # flatten the local shard so the scatter dim tiles the axis
        flat = x.reshape(-1)
        pad = (-flat.shape[0]) % n_shards
        if pad:
            flat = jnp.pad(flat, (0, pad))
        return lax.psum_scatter(flat, axis, tiled=True)

    def a2a(x):
        flat = x.reshape(-1)
        pad = (-flat.shape[0]) % n_shards
        if pad:
            flat = jnp.pad(flat, (0, pad))
        return lax.all_to_all(flat, axis, split_axis=0, concat_axis=0,
                              tiled=True)

    def permute(x):
        perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]
        return lax.ppermute(x, axis, perm)

    fns = {"all_reduce": (ar, spec, spec),
           "all_gather": (ag, spec, spec),
           "reduce_scatter": (rs, spec, spec),
           "all_to_all": (a2a, spec, spec),
           "collective_permute": (permute, spec, spec)}
    if kind not in fns:
        raise KeyError(f"unknown collective {kind!r}")
    fn, in_spec, out_spec = fns[kind]
    return shard_map(fn, mesh=mesh, in_specs=(in_spec,), out_specs=out_spec,
                     check_rep=False)
