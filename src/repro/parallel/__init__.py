"""Distribution: logical-axis sharding, collectives, pipeline parallelism."""
from . import sharding
from .sharding import default_rules, shard, spec_for, use_rules

__all__ = ["sharding", "default_rules", "shard", "spec_for", "use_rules"]
