"""End-to-end CLI smoke: every registered verb drives a tiny trace in a
tmpdir, in-process through ``repro.cli.main`` (no subprocesses, no model
compilation).  The verbs with no prior coverage — capture, convert, feed,
replay, bench, explore — get their first exercise here; the analyze test
additionally pins the CHKB-v4 columnar fast path to the node-object path's
byte-identical output."""
import json
import os

import pytest

from repro import cli
from repro.core import generator
from repro.core.serialization import ChkbReader, save


@pytest.fixture(scope="module")
def workdir(tmp_path_factory):
    """One shared tmpdir: capture once, drive every other verb off it."""
    tmp = tmp_path_factory.mktemp("cli")
    trace = str(tmp / "trace.chkb")
    assert cli.main(["capture", "--generate", "dp_allreduce",
                     "--opt", "steps=2", "--opt", "layers=3",
                     "--opt", "ranks=4", "-o", trace]) == 0
    canon = str(tmp / "canon.chkb")
    assert cli.main(["convert", trace, "-o", canon, "--window", "16"]) == 0
    return {"dir": tmp, "trace": trace, "canon": canon}


def test_capture_and_convert_wrote_chkb(workdir):
    with ChkbReader(workdir["canon"]) as r:
        assert r.version == 4 and r.node_count > 0


def test_feed(workdir, capsys):
    out = str(workdir["dir"] / "feed.json")
    assert cli.main(["feed", workdir["canon"], "--policy", "comm_priority",
                     "-o", out]) == 0
    stats = json.load(open(out))
    assert stats["nodes_fed"] > 0 and stats["policy"] == "comm_priority"


def test_sim_both_fidelities(workdir, capsys):
    for fid in ("analytic", "link"):
        out = str(workdir["dir"] / f"sim_{fid}.json")
        assert cli.main(["sim", workdir["canon"], "--topology", "ring",
                         "--ranks", "4", "--fidelity", fid, "-o", out]) == 0
        doc = json.load(open(out))
        assert doc["makespan_s"] > 0 and doc["fidelity"] == fid
    assert "makespan" in capsys.readouterr().out


def test_replay_compute_dry_run(workdir, capsys):
    assert cli.main(["replay", workdir["canon"], "--mode", "compute",
                     "--limit", "4"]) == 0
    assert "replayed" in capsys.readouterr().out


def test_analyze_v4_fast_path_byte_identical(workdir, tmp_path):
    # v4 rides the columnar path; a v3 rewrite of the same trace takes the
    # node-object fallback — the emitted documents must match byte-for-byte
    out4 = str(tmp_path / "a4.json")
    assert cli.main(["analyze", workdir["canon"], "-o", out4]) == 0
    from repro.core.serialization import load
    et = load(workdir["canon"])
    p3 = str(tmp_path / "canon3.chkb")
    save(et, p3, version=3)
    out3 = str(tmp_path / "a3.json")
    assert cli.main(["analyze", p3, "-o", out3]) == 0
    b4, b3 = open(out4, "rb").read(), open(out3, "rb").read()
    assert b4 == b3
    doc = json.loads(b4)
    assert doc["nodes"] > 0 and doc["op_counts"]
    # --deep still works on v4 (falls back to the materializing path)
    deep = str(tmp_path / "deep.json")
    assert cli.main(["analyze", workdir["canon"], "--deep",
                     "-o", deep]) == 0
    assert "critical_path" in json.load(open(deep))


def test_profile_then_synth_then_sim(workdir, capsys):
    prof = str(workdir["dir"] / "profile.json")
    assert cli.main(["profile", workdir["canon"], "-o", prof]) == 0
    out_dir = str(workdir["dir"] / "synth")
    assert cli.main(["synth", "-p", prof, "-o", out_dir, "--ranks", "2",
                     "--steps", "2", "--sim"]) == 0
    out = capsys.readouterr().out
    assert "synthesized" in out and "makespan" in out
    assert len(os.listdir(out_dir)) == 2


def test_synth_scenario_listing(capsys):
    assert cli.main(["synth", "--list"]) == 0
    assert "moe-mixed" in capsys.readouterr().out


def test_stages_lists_all_kinds(capsys):
    assert cli.main(["stages"]) == 0
    out = capsys.readouterr().out
    for name in ("generate", "convert", "sim", "synth.generate",
                 "explore.run", "explore.report", "perf_feeder",
                 "serve.api"):
        assert name in out, name


def test_bench_json_sidecar(workdir):
    out = str(workdir["dir"] / "bench.json")
    assert cli.main(["bench", "perf_feeder", "--scale", "smoke",
                     "--no-baseline", "--json", out]) == 0
    doc = json.load(open(out))
    assert doc["schema"] == "repro-bench-perf/v1"
    assert doc["perf_feeder"]["drain"][0]["nodes_per_sec"] > 0


def test_explore_sweep_and_cache(workdir, capsys):
    spec = {"name": "cli-mini",
            "workloads": [{"pattern": "moe_mixed",
                           "args": {"mode": "mixed", "iters": 2}}],
            "axes": {"topology": ["ring", "switch", "clos"],
                     "world_size": [4]}}
    sp = str(workdir["dir"] / "spec.json")
    json.dump(spec, open(sp, "w"))
    cache = str(workdir["dir"] / "cache")
    report = str(workdir["dir"] / "report.md")
    rj = str(workdir["dir"] / "report.json")
    assert cli.main(["explore", sp, "--jobs", "1", "--cache-dir", cache,
                     "--report", report, "--json", rj]) == 0
    out = capsys.readouterr().out
    assert "3 simulated, 0 cached" in out
    assert cli.main(["explore", sp, "--jobs", "1",
                     "--cache-dir", cache]) == 0
    assert "0 simulated, 3 cached" in capsys.readouterr().out
    assert "Pareto" in open(report).read()
    doc = json.load(open(rj))
    assert doc["workloads"]["moe_mixed-mixed"]["best"]["makespan_s"] > 0


def test_explore_dry_run_deterministic(workdir, capsys):
    spec = {"workloads": [{"pattern": "dp_allreduce"}],
            "axes": {"topology": ["ring", "switch"]}}
    sp = str(workdir["dir"] / "dry.json")
    json.dump(spec, open(sp, "w"))
    assert cli.main(["explore", sp, "--dry-run"]) == 0
    a = capsys.readouterr().out
    assert cli.main(["explore", sp, "--dry-run"]) == 0
    assert a == capsys.readouterr().out
    doc = json.loads(a)
    assert doc["total"] == 2 and all(len(c["hash"]) == 64
                                     for c in doc["configs"])


def test_cli_error_paths(capsys, tmp_path):
    assert cli.main(["sim", str(tmp_path / "missing.chkb")]) == 2
    assert cli.main(["capture", "--generate", "nonsense",
                     "-o", str(tmp_path / "x.chkb")]) == 2
    assert "error:" in capsys.readouterr().err
    # unbindable port: one-line error + exit 2, never a traceback
    assert cli.main(["serve-api", "--port", "99999"]) == 2
    assert "cannot bind" in capsys.readouterr().err


def test_serve_api_cli_roundtrip(tmp_path, capsys):
    # drive the real verb in a thread: ephemeral port via --port-file,
    # submit over HTTP, then stop through the module's active-service hook
    import json as _json
    import threading
    import time
    import urllib.request

    from repro.serve_api.server import _ACTIVE

    port_file = str(tmp_path / "port")
    rc = []
    t = threading.Thread(target=lambda: rc.append(cli.main(
        ["serve-api", "--port", "0", "--port-file", port_file,
         "--state-dir", str(tmp_path / "state"),
         "--cache-dir", str(tmp_path / "cache"),
         "--workers", "1", "--retries", "1", "-q"])))
    t.start()
    try:
        deadline = time.monotonic() + 30
        while not os.path.exists(port_file):
            assert time.monotonic() < deadline, "daemon never bound"
            time.sleep(0.02)
        host, port = open(port_file).read().split()
        base = f"http://{host}:{port}"
        with urllib.request.urlopen(base + "/healthz") as r:
            assert _json.loads(r.read())["ok"] is True
        spec = {"workloads": [{"pattern": "dp_allreduce"}],
                "axes": {"world_size": [4]}}
        req = urllib.request.Request(base + "/api/v1/sweeps",
                                     data=_json.dumps(spec).encode(),
                                     method="POST")
        with urllib.request.urlopen(req) as r:
            jid = _json.loads(r.read())["id"]
        while True:
            with urllib.request.urlopen(base + f"/api/v1/sweeps/{jid}") as r:
                st = _json.loads(r.read())
            if st["state"] in ("done", "failed"):
                break
            assert time.monotonic() < deadline, st
            time.sleep(0.02)
        assert st["state"] == "done", st
        with urllib.request.urlopen(base + "/metrics") as r:
            assert "repro_sweep_runs_total" in r.read().decode()
    finally:
        _ACTIVE[-1].request_stop()
        t.join(timeout=60)
    assert rc == [0]
