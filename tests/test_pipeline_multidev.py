"""Pipeline parallelism + multi-device collectives — run in a subprocess
with 4 forced host devices (the main test process must keep 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


def test_pipeline_forward_matches_direct():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_host_mesh
        from repro.parallel.pipeline import (bubble_fraction,
                                             pipeline_forward, stage_stack)
        mesh = jax.make_mesh((4,), ("stage",))
        L, D, M, mb = 4, 8, 4, 2
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (L, D, D)) * 0.3

        def stage_fn(params, x):        # params: [L/S, D, D]
            for i in range(params.shape[0]):
                x = jnp.tanh(x @ params[i])
            return x

        xs = jax.random.normal(key, (M, mb, D))
        piped = jax.jit(pipeline_forward(stage_fn, mesh, "stage", M))
        y = piped(stage_stack(w, 4), xs)
        # direct reference: all layers applied to every microbatch
        ref = xs
        for i in range(L):
            ref = jnp.tanh(ref @ w[i])
        import numpy as np
        err = float(jnp.max(jnp.abs(y - ref)))
        print("ERR", err)
        print("BUBBLE", bubble_fraction(4, M))
    """)
    err = float(out.split("ERR")[1].split()[0])
    assert err < 1e-4, out
    assert abs(float(out.split("BUBBLE")[1].split()[0]) - 3 / 7) < 1e-6


def test_shard_map_collectives_multidev():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.collectives import make_collective_fn
        mesh = jax.make_mesh((4,), ("data",))
        x = jnp.arange(16.0).reshape(4, 4)
        ar = make_collective_fn("all_reduce", mesh, "data")(x)
        np.testing.assert_allclose(np.asarray(ar)[0],
                                   np.asarray(x).sum(0))
        rs = make_collective_fn("reduce_scatter", mesh, "data")(x)
        assert rs.size == 4 and np.isfinite(np.asarray(rs)).all()
        a2a = make_collective_fn("all_to_all", mesh, "data")(x)
        assert a2a.size == 16 and np.isfinite(np.asarray(a2a)).all()
        ag = make_collective_fn("all_gather", mesh, "data")(x)
        assert ag.size == 64
        print("OK")
    """)
    assert "OK" in out


def test_compressed_psum_multidev():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.parallel.collectives import compressed_psum_grads
        mesh = jax.make_mesh((4,), ("data",))
        g = {"w": jnp.arange(32.0).reshape(4, 8) / 37.0}
        e = {"w": jnp.zeros((4, 8))}

        def f(g, e):
            out, err = compressed_psum_grads(g, e, "data")
            return out, err

        fn = shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")),
                       out_specs=(P("data"), P("data")), check_rep=False)
        out, err = fn(g, e)
        truth = np.asarray(g["w"]).sum(0) / 4.0
        got = np.asarray(out["w"])[0]
        rel = np.abs(got - truth).max() / (np.abs(truth).max() + 1e-9)
        print("REL", rel)
    """)
    rel = float(out.split("REL")[1].split()[0])
    assert rel < 0.05, out
