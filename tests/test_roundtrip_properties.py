"""Round-trip preservation: every node field, every NodeType, both formats.

Regression anchor for the `_node_to_dict` bug where comm_bytes (and p2p
src/dst) were only serialized for communication nodes, silently zeroing
MEM_LOAD/MEM_STORE byte counts — and total_bytes() — after a save/load.
"""
import dataclasses
import random

import pytest

from repro.core import (CollectiveType, ETNode, ExecutionTrace, NodeType,
                        from_chkb_bytes, from_json_bytes, to_chkb_bytes,
                        to_json_bytes)
from repro.core.serialization import load, roundtrip_equal, save

FIELDS = [f.name for f in dataclasses.fields(ETNode)]


def full_node(i: int, ntype: NodeType) -> ETNode:
    """A node with EVERY field set to a non-default value."""
    return ETNode(
        id=i, name=f"node/{ntype.name.lower()}/{i}", type=ntype,
        ctrl_deps=[max(0, i - 1)] if i else [],
        data_deps=[max(0, i - 2)] if i > 1 else [],
        sync_deps=[max(0, i - 3)] if i > 2 else [],
        start_time_micros=10.5 * (i + 1),
        duration_micros=3.25 * (i + 1),
        inputs=[i * 2], outputs=[i * 2 + 1],
        comm_type=CollectiveType.ALL_GATHER,
        comm_group=0, comm_tag=f"tag{i}",
        comm_bytes=1000 + i, comm_src=i, comm_dst=i + 1,
        attrs={"op": "dot_general", "flops": 1.5e9, "nested": {"k": [1, 2]}},
    )


def minimal_comm_bytes_node(i: int, ntype: NodeType) -> ETNode:
    """The regression shape: byte count WITHOUT a collective type."""
    return ETNode(id=i, name=f"mem{i}", type=ntype, comm_bytes=4096 + i,
                  comm_src=2, comm_dst=3)


def build_trace(node_fn) -> ExecutionTrace:
    et = ExecutionTrace(rank=1, world_size=4, metadata={"m": 1})
    et.add_process_group([0, 1, 2, 3], tag="dp")
    et.add_tensor((4, 8), "bf16")
    for i, ntype in enumerate(NodeType):
        et.add_node(node_fn(i, ntype))
    return et


def assert_nodes_equal(a: ExecutionTrace, b: ExecutionTrace) -> None:
    assert sorted(a.nodes) == sorted(b.nodes)
    for nid in a.nodes:
        na, nb = a.nodes[nid], b.nodes[nid]
        for f in FIELDS:
            assert getattr(na, f) == getattr(nb, f), (
                f"field {f} of node {nid} ({na.type.name}) changed: "
                f"{getattr(na, f)!r} -> {getattr(nb, f)!r}")


@pytest.mark.parametrize("codec", ["json", "chkb"])
@pytest.mark.parametrize("node_fn", [full_node, minimal_comm_bytes_node])
def test_every_field_every_nodetype_roundtrips(codec, node_fn):
    et = build_trace(node_fn)
    if codec == "json":
        back = from_json_bytes(to_json_bytes(et))
    else:
        back = from_chkb_bytes(to_chkb_bytes(et, block_size=3))
    assert_nodes_equal(et, back)
    assert roundtrip_equal(et, back)


@pytest.mark.parametrize("suffix", ["t.json", "t.json.zst", "t.chkb"])
def test_mem_node_bytes_survive_save_load(tmp_path, suffix):
    # the fig7 bandwidth benchmark reads total_bytes() after a save/load;
    # MEM_LOAD/MEM_STORE counts must not be dropped
    et = ExecutionTrace()
    et.add_node(name="ld", type=NodeType.MEM_LOAD, comm_bytes=1 << 20)
    et.add_node(name="st", type=NodeType.MEM_STORE, comm_bytes=1 << 19)
    et.add_node(name="dl", type=NodeType.DATA_LOAD, comm_bytes=1 << 18)
    total = et.total_bytes()
    assert total == (1 << 20) + (1 << 19) + (1 << 18)
    p = str(tmp_path / suffix)
    save(et, p)
    back = load(p)
    assert back.total_bytes() == total
    assert back.total_bytes(NodeType.MEM_LOAD) == 1 << 20
    assert back.total_bytes(NodeType.MEM_STORE) == 1 << 19


def test_p2p_src_dst_survive_without_comm_type():
    et = ExecutionTrace()
    et.add_node(name="x", type=NodeType.MEM_STORE, comm_bytes=64,
                comm_src=1, comm_dst=2)
    back = from_json_bytes(to_json_bytes(et))
    n = back.nodes[0]
    assert (n.comm_bytes, n.comm_src, n.comm_dst) == (64, 1, 2)


@pytest.mark.parametrize("seed", range(10))
def test_random_traces_double_roundtrip_stable(seed):
    rng = random.Random(seed)
    et = ExecutionTrace(rank=rng.randint(0, 7), world_size=8)
    pg = et.add_process_group(range(8), tag="ep")
    for i in range(rng.randint(1, 120)):
        ntype = rng.choice(list(NodeType))
        n = et.add_node(name=f"n{i}", type=ntype,
                        duration_micros=rng.uniform(0, 50),
                        comm_bytes=rng.randint(0, 1 << 16))
        if ntype in (NodeType.COMM_COLL, NodeType.COMM_SEND,
                     NodeType.COMM_RECV):
            n.comm_type = rng.choice(list(CollectiveType)[1:])
            n.comm_group = pg.id
        if i:
            n.data_deps.append(rng.randrange(i))
    j1 = to_json_bytes(et)
    j2 = to_json_bytes(from_json_bytes(j1))
    assert j1 == j2                       # serialization is a fixed point
    c1 = to_chkb_bytes(et, block_size=7)
    c2 = to_chkb_bytes(from_chkb_bytes(c1), block_size=7)
    assert c1 == c2
