"""repro.pipeline: registry, fluent chaining, windowed streaming equivalence,
deprecation shims, and the CLI."""
import json

import pytest

from repro.core import (ExecutionTrace, NodeType, convert, convert_trace,
                        link, link_traces, load, save, to_chkb_bytes)
from repro.core.generator import (compute_chain, dp_allreduce_pattern,
                                  moe_mixed_collectives)
from repro.pipeline import (Pipeline, TraceStream, WindowPass,
                            available_stages, get_stage, make_stage,
                            register_stage)


def big_trace(n: int = 12000) -> ExecutionTrace:
    """>=10k-node generated trace (acceptance criterion size)."""
    et = dp_allreduce_pattern(steps=n // 20, layers=10, ranks=8)
    assert len(et) >= n // 20 * 20
    return et


# ---------------------------------------------------------------- registry
def test_registry_lookup_and_instantiation():
    assert get_stage("pass", "convert") is not None
    p = make_stage("pass", "scale_time", factor=2.0)
    assert p.factor == 2.0
    kinds = available_stages()
    assert {"source", "pass", "sink"} <= set(kinds)
    assert "capture" in kinds["source"] and "chkb" in kinds["source"]
    assert {"link", "convert", "scale_time", "filter"} <= set(kinds["pass"])
    assert {"chkb", "json", "analyze", "sim", "replay", "feed"} <= set(
        kinds["sink"])


def test_registry_unknown_stage_lists_options():
    with pytest.raises(KeyError, match="convert"):
        get_stage("pass", "nonexistent")


def test_register_stage_decorator_and_duplicate_guard():
    @register_stage("negate_time_test", kind="pass")
    class NegatePass(WindowPass):
        def transform(self, nodes):
            for n in nodes:
                n.duration_micros = -n.duration_micros
            return nodes

    out = (Pipeline.from_source(compute_chain(5), window=2)
           .then("negate_time_test").sink("trace").run())
    assert all(n.duration_micros == -100.0 for n in out)
    with pytest.raises(ValueError, match="already registered"):
        register_stage("negate_time_test", kind="pass")(NegatePass)


# ---------------------------------------------------------------- chaining
def test_fluent_chain_scale_convert_analyze():
    et = moe_mixed_collectives(iters=4, ranks=8)
    pipe = (Pipeline.from_source(et, window=4)
            .then("scale_time", factor=0.5, node_type="COMP")
            .then("convert")
            .sink("analyze"))
    stats = pipe.run()
    assert stats["nodes"] == len(et)
    assert stats["op_counts"]["AllReduce"] == 4
    assert "convert" in pipe.reports and "scale_time" in pipe.reports
    # source trace must be untouched (window passes copy nodes)
    assert all(n.duration_micros >= 0 and
               "passes" not in et.metadata for n in et)


def test_pipeline_without_sink_materializes():
    et = compute_chain(10)
    out = Pipeline.from_source(et).run()
    assert isinstance(out, ExecutionTrace) and len(out) == 10


def test_link_pass_merges_host_device():
    host = compute_chain(4)
    device = compute_chain(3)
    out = (Pipeline.from_source(host).then("link", device=device)
           .then("convert").sink("trace").run())
    assert len(out) == 7
    assert out.metadata.get("linked") and out.metadata.get("converted")


def test_filter_pass_splices_deps():
    et = compute_chain(6)            # 0 <- 1 <- ... <- 5 chain
    for n in et:
        if n.id % 2:
            n.name = f"drop/{n.id}"
    out = (Pipeline.from_source(et, window=2)
           .then("filter", name_re=r"^drop/").sink("trace").run())
    assert sorted(out.nodes) == [0, 2, 4]
    # deps spliced through the dropped odd nodes: 4 -> 2 -> 0
    assert out.nodes[2].data_deps == [0]
    assert out.nodes[4].data_deps == [2]
    assert out.is_acyclic()


# ------------------------------------------------- streaming equivalence
def test_windowed_chkb_byte_identical_to_in_memory(tmp_path):
    et = big_trace()
    assert len(et) >= 10_000
    src = str(tmp_path / "big.chkb")
    save(et, src, block_size=512)
    # in-memory path
    expected = to_chkb_bytes(load(src))
    # windowed streaming path: small windows, never materializes
    out = (Pipeline.from_source("chkb", src, window=64)
           .sink("chkb", str(tmp_path / "streamed.chkb")).run())
    streamed = open(out, "rb").read()
    assert streamed == expected


def test_windowed_pass_chain_equals_in_memory(tmp_path):
    et = big_trace()
    src = str(tmp_path / "big.chkb")
    save(et, src)
    out_w = (Pipeline.from_source("chkb", src, window=32)
             .then("scale_time", factor=0.25)
             .sink("chkb", str(tmp_path / "w.chkb")).run())
    out_m = (Pipeline.from_source(load(src), window=10 ** 9)
             .then("scale_time", factor=0.25)
             .sink("chkb", str(tmp_path / "m.chkb")).run())
    assert open(out_w, "rb").read() == open(out_m, "rb").read()


def test_stream_window_sizes_respected():
    et = compute_chain(100)
    stream = TraceStream.from_trace(et, window=16)
    sizes = [len(w) for w in stream.windows()]
    assert sum(sizes) == 100
    assert all(s <= 16 for s in sizes)
    with pytest.raises(RuntimeError, match="consumed"):
        next(stream.windows())


def test_analyze_sink_matches_whole_trace_analysis():
    from repro.core import analysis
    et = moe_mixed_collectives(iters=6, ranks=8)
    stats = Pipeline.from_source(et, window=3).sink("analyze").run()
    assert stats["op_counts"] == analysis.op_counts(et)
    assert stats["total_bytes"] == et.total_bytes()


def test_dirty_trace_repairable_through_pipeline(tmp_path):
    # dangling dep + self-dep: the stream must not stall before the converter
    # pass (the repair tool) gets to run — both in memory and from a file
    et = ExecutionTrace()
    a = et.add_node(name="a", type=NodeType.COMP)
    b = et.add_node(name="b", type=NodeType.COMP)
    b.data_deps.extend([a.id, 999])       # 999 never exists
    a.ctrl_deps.append(a.id)              # self-dep
    out = Pipeline.from_source(et, window=1).then("convert").sink("trace").run()
    assert len(out) == 2 and out.is_acyclic()
    assert all(999 not in n.data_deps and n.id not in n.ctrl_deps
               for n in out)
    p = str(tmp_path / "dirty.chkb")
    save(et, p)
    out2 = (Pipeline.from_source("chkb", p, window=1)
            .then("convert").sink("trace").run())
    assert out2.to_dict()["nodes"] == out.to_dict()["nodes"]


def test_trace_pass_does_not_mutate_source_trace():
    et = ExecutionTrace()
    c = et.add_node(name="coll", type=NodeType.COMM_COLL)   # INVALID comm_type
    d = et.add_node(name="dep", type=NodeType.COMP)
    d.data_deps.append(c.id)
    d.ctrl_deps.append(c.id)              # redundant ctrl dep: convert prunes
    out = Pipeline.from_source(et).then("convert").sink("trace").run()
    from repro.core.schema import CollectiveType
    assert out.nodes[0].comm_type == CollectiveType.ALL_REDUCE  # repaired copy
    assert et.nodes[c.id].comm_type == CollectiveType.INVALID   # source intact
    assert et.nodes[d.id].ctrl_deps == [c.id]


# ------------------------------------------------------ deprecation shims
def test_old_entry_points_still_work_with_warning():
    host = compute_chain(3)
    device = compute_chain(2)
    with pytest.warns(DeprecationWarning, match="link"):
        merged, rep = link(host, device)
    assert len(merged) == 5 and rep.host_nodes == 3
    with pytest.warns(DeprecationWarning, match="convert"):
        out, crep = convert(merged)
    assert len(out) == 5 and crep.nodes_out == 5
    # canonical impls match and stay silence-clean
    merged2, _ = link_traces(compute_chain(3), compute_chain(2))
    out2, _ = convert_trace(merged2)
    assert out.to_dict()["nodes"] == out2.to_dict()["nodes"]


# ------------------------------------------------------------------- CLI
def test_cli_end_to_end(tmp_path, capsys):
    from repro.cli import main
    t = str(tmp_path / "t.chkb")
    c = str(tmp_path / "c.chkb")
    stats_p = str(tmp_path / "stats.json")
    assert main(["capture", "--generate", "dp_allreduce", "--opt", "steps=2",
                 "--opt", "layers=3", "--opt", "ranks=4", "-o", t]) == 0
    assert main(["convert", t, "-o", c, "--window", "8"]) == 0
    assert main(["analyze", c, "--deep", "-o", stats_p]) == 0
    stats = json.load(open(stats_p))
    assert stats["nodes"] == 14 and "critical_path" in stats
    assert main(["feed", c, "--policy", "comm_priority"]) == 0
    out = capsys.readouterr().out
    assert '"nodes_fed": 14' in out
    assert main(["stages"]) == 0
    assert "scale_time" in capsys.readouterr().out
