"""Simulator: topology ordering (Fig 12), bandwidth scaling (Fig 7),
mixed-collective long tail (Figs 10/11), stragglers, replay."""
import numpy as np
import pytest

from repro.core import generator
from repro.core.infragraph import TPU_V5E
from repro.sim import (Fabric, ReplayConfig, Replayer, SimConfig, Simulator,
                       collective_accuracy_check, simulate_single_trace)


def test_topology_ordering_fig12():
    """switch <= ring <= fully_connected at equal end-link bandwidth."""
    results = {}
    for topo in ("switch", "ring", "fully_connected"):
        et = generator.moe_mixed_collectives(iters=4, ranks=8)
        results[topo] = simulate_single_trace(et, Fabric.build(topo, 8)
                                              ).makespan_s
    assert results["switch"] <= results["ring"] <= results["fully_connected"]


def test_bandwidth_scaling_converges_fig12():
    """Communication time stops improving as bandwidth grows (latency
    becomes dominant) — the paper's second Fig 12 observation."""
    times = []
    for bw_gbps in (75, 150, 300, 600, 1200, 2400):
        et = generator.moe_mixed_collectives(iters=2, ranks=8,
                                             alltoall_bytes=1 << 16,
                                             allreduce_bytes=1 << 16)
        fab = Fabric.build("switch", 8, link_bw=bw_gbps * 1e9)
        times.append(simulate_single_trace(et, fab).makespan_s)
    assert times[0] > times[-1]
    gain_early = times[0] / times[1]
    gain_late = times[-2] / times[-1]
    assert gain_late < gain_early       # diminishing returns
    assert gain_late < 1.35             # converged: latency-dominated


def test_bandwidth_ratio_fig7():
    """4x lower bandwidth => ~4x slower All2All/AllGather; AllReduce (small
    payloads here) degrades sub-linearly — the paper's Fig 7 observation."""
    def run(bw):
        et = generator.moe_mixed_collectives(iters=4, ranks=8,
                                             alltoall_bytes=64 << 20,
                                             allreduce_bytes=256 << 10)
        cfgd = SimConfig(congestion=False)
        return simulate_single_trace(et, Fabric.build("switch", 8,
                                                      link_bw=bw), cfgd)
    fast = run(400e9 / 8)
    slow = run(100e9 / 8)
    a2a_ratio = (slow.collective_time_s["All2All"]
                 / fast.collective_time_s["All2All"])
    ar_ratio = (slow.collective_time_s["AllReduce"]
                / fast.collective_time_s["AllReduce"])
    assert 3.5 < a2a_ratio <= 4.1
    assert ar_ratio < a2a_ratio         # latency-heavier => sub-linear


def test_mixed_collectives_long_tail_fig11():
    """Mixing All-Reduce with All-to-All long-tails the A2A FCT
    distribution vs isolation (the §5.3 DCQCN finding)."""
    iso = simulate_single_trace(
        generator.moe_mixed_collectives(iters=6, ranks=8, mode="alltoall"),
        Fabric.build("switch", 8))
    mixed = simulate_single_trace(
        generator.moe_mixed_collectives(iters=6, ranks=8),
        Fabric.build("switch", 8))

    def p99_over_p50(res):
        fcts = sorted(f.fct_s for f in res.flows if f.kind == "All2All")
        return fcts[-1] / max(fcts[len(fcts) // 2], 1e-12)

    assert p99_over_p50(mixed) > p99_over_p50(iso)
    mixed_a2a = [f for f in mixed.flows if f.kind == "All2All"]
    assert any(f.throttled > 1.0 for f in mixed_a2a)


def test_straggler_slows_compute_bound_job():
    traces = [generator.dp_allreduce_pattern(steps=2, layers=4, ranks=4,
                                             compute_us=5000.0,
                                             grad_bytes=1 << 16, rank=r)
              for r in range(4)]
    fab = Fabric.build("switch", 4)
    base = Simulator(traces, fab, SimConfig()).run()
    slow = Simulator(traces, fab,
                     SimConfig(speed_factors={1: 0.4})).run()
    assert slow.makespan_s > base.makespan_s * 1.5


def test_multirank_rendezvous_synchronizes():
    traces = [generator.dp_allreduce_pattern(steps=1, layers=2, ranks=2,
                                             rank=r) for r in range(2)]
    res = Simulator(traces, Fabric.build("switch", 2)).run()
    assert res.makespan_s > 0
    assert "AllReduce" in res.collective_time_s
    # both ranks finish at the same collective-gated time
    assert abs(res.per_rank_finish_s[0] - res.per_rank_finish_s[1]) < 1e-9


def test_replay_modes():
    et = generator.dp_allreduce_pattern(steps=1, layers=3, ranks=4)
    full = Replayer(et, ReplayConfig(mode="full")).run()
    comm = Replayer(et, ReplayConfig(mode="comm")).run()
    comp = Replayer(et, ReplayConfig(mode="compute")).run()
    assert full.comm_nodes == comm.comm_nodes > 0
    assert full.compute_nodes == comp.compute_nodes > 0
    assert comm.compute_nodes == 0 and comp.comm_nodes == 0
    # lazy vs preallocate execute the same node set
    lazy = Replayer(et, ReplayConfig(mode="full",
                                     allocation="lazy")).run()
    assert lazy.nodes_executed == full.nodes_executed


def test_replay_subrange():
    et = generator.compute_chain(n=10)
    rep = Replayer(et, ReplayConfig(mode="compute",
                                    node_range=(2, 5))).run()
    assert rep.compute_nodes == 3


def test_collective_accuracy_checker():
    rows = collective_accuracy_check(sizes=(4096,), group=8)
    by = {(r["dtype"], r["algo"]): r["rel_err_mean"] for r in rows}
    # lower precision => larger reduction error; order-dependence visible
    assert by[("bfloat16", "ring")] > by[("float32", "ring")]
    assert by[("float16", "ring")] > by[("float32", "ring")]
    assert all(r["rel_err_mean"] >= 0 for r in rows)
