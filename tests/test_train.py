"""Training substrate: optimization progress, checkpoint/restart
bit-exactness under injected failures, ZeRO-1 spec derivation, data
determinism, gradient compression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as config_base
from repro.models import model_zoo
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, SyntheticLM
from repro.train.fault_tolerance import InjectedFailure, run_with_restarts
from repro.train.optimizer import AdamWConfig, zero1_spec
from repro.train.train_step import init_train_state, make_train_step
from jax.sharding import PartitionSpec as P


def _tiny_setup(rng_key, n_micro=1):
    cfg = config_base.get("granite-8b").reduced()
    model = model_zoo.build(cfg, model_axis=1)
    state = init_train_state(model, rng_key)
    opt = AdamWConfig(peak_lr=1e-2, warmup_steps=2, total_steps=50)
    step = jax.jit(make_train_step(model, opt, n_micro=n_micro))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=16,
                                  global_batch=4))
    return model, state, step, data


def test_loss_decreases(rng_key):
    model, state, step, data = _tiny_setup(rng_key)
    losses = []
    for i in range(8):
        state, metrics = step(state, data.batch_at(i % 2))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses


def test_microbatched_grad_matches_full(rng_key):
    """n_micro=2 must produce the same update as the full batch."""
    model, state, _, data = _tiny_setup(rng_key)
    opt = AdamWConfig()
    s1 = jax.jit(make_train_step(model, opt, n_micro=1))
    s2 = jax.jit(make_train_step(model, opt, n_micro=2))
    b = data.batch_at(0)
    out1, m1 = s1(state, b)
    out2, m2 = s2(state, b)
    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - b_.astype(jnp.float32))))
            for a, b_ in zip(jax.tree.leaves(out1["params"]),
                             jax.tree.leaves(out2["params"])))
    assert d < 2e-2, d    # bf16 params; microbatch mean is f32-accumulated


def test_checkpoint_roundtrip(tmp_path, rng_key):
    model, state, step, data = _tiny_setup(rng_key)
    state, _ = step(state, data.batch_at(0))
    path = ckpt.save(state, str(tmp_path), step=0)
    restored, got_step = ckpt.restore(state, str(tmp_path))
    assert got_step == 0
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restart_is_bit_exact(tmp_path, rng_key):
    """A crash at step 7 + restart from checkpoint reproduces the exact
    losses of an uninterrupted run (the fault-tolerance contract)."""
    total = 12
    model, state0, step, data = _tiny_setup(rng_key)

    clean = run_with_restarts(step, state0, data.batch_at,
                              total_steps=total,
                              ckpt_dir=str(tmp_path / "clean"),
                              save_every=4)
    faulty = run_with_restarts(step, state0, data.batch_at,
                               total_steps=total,
                               ckpt_dir=str(tmp_path / "faulty"),
                               save_every=4,
                               fail_at={7: InjectedFailure("node died")})
    assert faulty.restarts == 1
    assert clean.losses == faulty.losses


def test_data_pipeline_deterministic():
    d1 = SyntheticLM(DataConfig(vocab=100, seq_len=8, global_batch=2, seed=3))
    d2 = SyntheticLM(DataConfig(vocab=100, seq_len=8, global_batch=2, seed=3))
    for s in (0, 5, 11):
        np.testing.assert_array_equal(np.asarray(d1.batch_at(s)["tokens"]),
                                      np.asarray(d2.batch_at(s)["tokens"]))


def test_data_pipeline_records_trace_nodes():
    from repro.core import ExecutionTrace, NodeType
    et = ExecutionTrace()
    data = SyntheticLM(DataConfig(vocab=100, seq_len=8, global_batch=2,
                                  shards=4), trace=et)
    data.batch_at(0)
    data.batch_at(1)
    loads = [n for n in et if n.type == NodeType.DATA_LOAD]
    assert len(loads) == 8
    assert all(n.comm_bytes > 0 for n in loads)


def test_zero1_spec():
    import jax.sharding
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sp = zero1_spec(P(None, "model"), (64, 128), mesh, ("data",))
    # with |data| == 1 nothing changes
    assert sp == P(None, "model") or sp == P()


def test_grad_compression_error_feedback(rng_key):
    from repro.parallel.collectives import compress_grads, dequantize_int8
    g = {"w": jax.random.normal(rng_key, (256,), jnp.float32)}
    q, e, ratio = compress_grads(g)
    assert ratio <= 0.26          # int8 vs f32
    deq = dequantize_int8(*q["w"])
    # error feedback: residual == exactly the quantization error
    np.testing.assert_allclose(np.asarray(e["w"]),
                               np.asarray(g["w"] - deq), rtol=1e-6)
    # and a second pass with feedback reduces accumulated bias
    q2, e2, _ = compress_grads(g, e)
    two_step = dequantize_int8(*q2["w"]) + 0  # includes carried error
    assert float(jnp.mean(jnp.abs(e2["w"]))) <= float(
        jnp.mean(jnp.abs(g["w"]))) * 0.02
