"""Two-fidelity network model: phase decomposition, InfraGraph routing,
max-min fair sharing, Fig-12 emergent topology ordering, and the
tpu_pod / all-to-all-latency satellite fixes."""
import math

import pytest

from repro.core import generator
from repro.core.infragraph import TPU_V5E, LinkLoad, tpu_pod_2d
from repro.core.schema import CollectiveType
from repro.sim import (CollectiveModel, Fabric, LinkModel, SimConfig,
                       Simulator, build_network_model, decompose,
                       max_min_fair_rates, simulate_single_trace)
from repro.sim.topology import TOPOLOGIES, _torus_dims

GROUP = 8
PAYLOAD = 4 << 20
KINDS = [k for k in CollectiveType if k != CollectiveType.INVALID]


def _fabric(topo: str, mode: str, n: int = GROUP) -> Fabric:
    return Fabric.build(topo, n, mode=mode)


# ------------------------------------------------- decomposition invariants
@pytest.mark.parametrize("kind", KINDS)
def test_decompose_conserves_alpha_beta_volume(kind):
    """Per-rank bytes sent by the phase schedule match the alpha-beta
    model's bandwidth term (the two fidelities price the same traffic)."""
    phases = decompose(kind, GROUP)
    sent = [0.0] * GROUP
    for ph in phases:
        for f in ph.flows:
            sent[f.src] += f.frac * ph.repeat
    n = GROUP
    expected = {
        CollectiveType.ALL_REDUCE: 2 * (n - 1) / n,
        CollectiveType.ALL_GATHER: (n - 1) / n,
        CollectiveType.REDUCE_SCATTER: (n - 1) / n,
        CollectiveType.ALL_TO_ALL: (n - 1) / n,
        CollectiveType.COLLECTIVE_PERMUTE: 1.0,
        CollectiveType.BARRIER: 0.0,
    }
    if kind in expected:
        assert max(sent) == pytest.approx(expected[kind], rel=1e-9)
    if kind == CollectiveType.BROADCAST:
        # binomial tree: every rank receives the payload exactly once
        recv = [0.0] * GROUP
        for ph in phases:
            for f in ph.flows:
                recv[f.dst] += f.frac * ph.repeat
        assert all(r == pytest.approx(1.0) for r in recv[1:])


def test_decompose_trivial_group():
    for kind in KINDS:
        assert decompose(kind, 1) == ()


# --------------------------------------------- all TOPOLOGIES x collectives
@pytest.mark.parametrize("topo", TOPOLOGIES)
@pytest.mark.parametrize("mode", ("analytic", "link"))
def test_zero_time_at_trivial_group(topo, mode):
    net = _fabric(topo, mode).network_model(CollectiveModel())
    for kind in KINDS:
        assert net.collective_time(kind, float(PAYLOAD), 1) == 0.0
        assert net.collective_time(kind, float(PAYLOAD), 0) == 0.0


@pytest.mark.parametrize("topo", TOPOLOGIES)
@pytest.mark.parametrize("mode", ("analytic", "link"))
def test_monotone_in_payload(topo, mode):
    net = _fabric(topo, mode).network_model(CollectiveModel())
    for kind in KINDS:
        times = [net.collective_time(kind, float(p), GROUP)
                 for p in (1 << 10, 1 << 16, 1 << 22, 1 << 26)]
        assert all(t >= 0.0 for t in times), kind
        assert all(b >= a for a, b in zip(times, times[1:])), kind
        if kind != CollectiveType.BARRIER:        # barrier is latency-only
            assert times[-1] > times[0], kind


@pytest.mark.parametrize("topo", TOPOLOGIES)
def test_link_time_at_least_store_and_forward_bound(topo):
    """Routed completion can never beat the store-and-forward lower bound
    of its own routed paths (full link bandwidth, zero contention)."""
    net = _fabric(topo, "link").network_model(CollectiveModel())
    assert isinstance(net, LinkModel)
    for kind in KINDS:
        t = net.collective_time(kind, float(PAYLOAD), GROUP)
        lb = net.lower_bound(kind, float(PAYLOAD), GROUP)
        assert t >= lb * (1 - 1e-12), (kind, t, lb)
        if kind != CollectiveType.BARRIER:
            assert lb > 0.0, kind


# ------------------------------------------------------------ routing layer
@pytest.mark.parametrize("topo", TOPOLOGIES)
def test_routing_paths_are_contiguous(topo):
    g = _fabric(topo, "link").graph
    routes = g.routing()
    assert g.routing() is routes          # cached per fabric
    for src in list(g.npus)[:4]:
        for dst in list(g.npus)[:4]:
            path = routes.path(src, dst)
            if src == dst:
                assert path == ()
                continue
            at = src
            for idx in path:
                link = g.links[idx]
                assert link.src == at
                at = link.dst
            assert at == dst


def test_ring_routing_takes_shortest_arc():
    g = Fabric.build("ring", 8, mode="link").graph
    routes = g.routing()
    assert len(routes.path(0, 1)) == 1
    assert len(routes.path(0, 4)) == 4
    assert len(routes.path(0, 7)) == 1    # wraps the short way


def test_link_load_accounting():
    g = Fabric.build("ring", 4, mode="link").graph
    routes = g.routing()
    load = LinkLoad(routes)
    load.add(routes.path(0, 2), 100e9)
    load.add(routes.path(0, 1), 50e9)
    first_hop = routes.path(0, 1)[0]
    assert load.bytes_by_link[first_hop] == 150e9
    top = load.top(1, wall_s=10.0)
    assert top[0]["bytes"] == 150e9 and top[0]["busy_frac"] > 0


def test_max_min_fair_sharing_water_fills():
    # two flows share link 0 (bw 10); flow B also crosses link 1 (bw 4):
    # B is bottlenecked at 4, A picks up the slack (6) — not an equal split
    rates = max_min_fair_rates([(0,), (0, 1)], [10.0, 4.0])
    assert rates[1] == pytest.approx(4.0)
    assert rates[0] == pytest.approx(6.0)
    # saturated equal split
    rates = max_min_fair_rates([(0,), (0,), (0,)], [9.0])
    assert rates == pytest.approx([3.0, 3.0, 3.0])


# ------------------------------------------------- emergent Fig-12 ordering
def _makespan(topo: str, mode: str, workload: str) -> float:
    et = generator.moe_mixed_collectives(iters=4, ranks=GROUP, mode=workload)
    return simulate_single_trace(et, _fabric(topo, mode)).makespan_s


def test_fig12_link_mode_allreduce_ring_beats_fully_connected():
    assert (_makespan("ring", "link", "allreduce")
            < _makespan("fully_connected", "link", "allreduce"))


def test_fig12_link_mode_a2a_switch_and_clos_beat_ring():
    ring_t = _makespan("ring", "link", "alltoall")
    assert _makespan("switch", "link", "alltoall") < ring_t
    assert _makespan("clos", "link", "alltoall") < ring_t


def test_fig12_link_mode_reranks_by_workload():
    """The paper's co-design point: the best topology depends on the
    workload's collective mix — no single fabric wins both."""
    def rank_order(workload):
        times = {t: _makespan(t, "link", workload)
                 for t in ("ring", "switch", "fully_connected")}
        return sorted(times, key=times.get)

    assert rank_order("allreduce") != rank_order("alltoall")
    assert rank_order("allreduce")[-1] == "fully_connected"


def test_link_mode_emergent_hop_dilution_without_fudge_factor():
    """Ring's a2a penalty must emerge from routed multi-hop flows: the
    link-mode gap vs switch exists even though a2a_hop_factor never enters
    the link path."""
    net = _fabric("ring", "link").network_model(CollectiveModel())
    switch_net = _fabric("switch", "link").network_model(CollectiveModel())
    ring_t = net.collective_time(CollectiveType.ALL_TO_ALL, 1e8, GROUP)
    switch_t = switch_net.collective_time(CollectiveType.ALL_TO_ALL, 1e8,
                                          GROUP)
    assert ring_t > switch_t


def test_link_stats_surface_busiest_links():
    traces = [generator.moe_mixed_collectives(iters=3, ranks=4, rank=r)
              for r in range(4)]
    res = Simulator(traces, _fabric("clos", "link", 4)).run()
    assert res.link_stats is not None
    assert res.link_stats["mode"] == "link"
    assert res.link_stats["links_touched"] > 0
    assert res.link_stats["time_cache"]["hits"] > 0
    assert len(res.link_stats["top_links"]) > 0
    # analytic mode reports none
    res_a = Simulator(traces, _fabric("clos", "analytic", 4)).run()
    assert res_a.link_stats is None


# ----------------------------------------------------------- satellite fixes
def test_tpu_pod_sized_from_rank_count():
    for n, dims in ((4, (2, 2)), (8, (2, 4)), (16, (4, 4)), (256, (16, 16))):
        assert _torus_dims(n) == dims
        fab = Fabric.build("tpu_pod", n)
        assert fab.graph.num_npus == n
    # the old behavior priced a 256-chip pod for ANY n
    assert Fabric.build("tpu_pod", 8).graph.num_npus == 8


@pytest.mark.parametrize("n", (1, 2, 3, 7, 13))
def test_tpu_pod_rejects_non_factorable_counts(n):
    with pytest.raises(ValueError, match="factorable"):
        Fabric.build("tpu_pod", n)


def test_a2a_latency_charged_per_peer():
    """ALL_TO_ALL setup latency scales with group size, like ring/tree
    charge per step — a flat latency_s under-charged large groups."""
    m = CollectiveModel()
    lat = 1e-6
    # tiny payload isolates the latency term
    t8 = m.time_s(CollectiveType.ALL_TO_ALL, 8.0, 8, 1e12, lat)
    t32 = m.time_s(CollectiveType.ALL_TO_ALL, 8.0, 32, 1e12, lat)
    assert t8 == pytest.approx(7 * lat, rel=1e-3)
    assert t32 == pytest.approx(31 * lat, rel=1e-3)


def test_bandwidth_term_unchanged_by_latency_fix():
    m = CollectiveModel()
    n, bw = 8, 50e9
    payload = 64 << 20
    t = m.time_s(CollectiveType.ALL_TO_ALL, float(payload), n, bw, 0.0)
    assert t == pytest.approx((n - 1) * payload / n / bw)


# ------------------------------------------------------------ wiring layers
def test_fabric_rejects_unknown_fidelity():
    with pytest.raises(ValueError, match="fidelity"):
        Fabric.build("switch", 8, mode="quantum")
    fab = Fabric.build("switch", 8)
    fab.mode = "quantum"
    with pytest.raises(ValueError, match="fidelity"):
        build_network_model(fab)


def test_sim_sink_fidelity_knob(tmp_path):
    from repro.core.serialization import save
    from repro.pipeline import Pipeline
    et = generator.moe_mixed_collectives(iters=3, ranks=4)
    p = str(tmp_path / "t.chkb")
    save(et, p)
    res_link = (Pipeline.from_source("load", p)
                .sink("sim", topology="ring", ranks=4, fidelity="link").run())
    res_ana = (Pipeline.from_source("load", p)
               .sink("sim", topology="ring", ranks=4).run())
    assert res_link.link_stats is not None
    assert res_ana.link_stats is None
    assert res_link.makespan_s > 0 and res_ana.makespan_s > 0


def test_cli_sim_fidelity(tmp_path, capsys):
    from repro.cli import main
    from repro.core.serialization import save
    et = generator.dp_allreduce_pattern(steps=1, layers=2, ranks=4)
    p = str(tmp_path / "t.chkb")
    out = str(tmp_path / "res.json")
    save(et, p)
    assert main(["sim", p, "--topology", "ring", "--ranks", "4",
                 "--fidelity", "link", "-o", out]) == 0
    import json
    doc = json.loads(open(out).read())
    assert doc["fidelity"] == "link"
    assert doc["link_stats"]["links_touched"] > 0


def test_replay_model_comparison():
    from repro.sim import ReplayConfig, Replayer
    et = generator.dp_allreduce_pattern(steps=1, layers=2, ranks=4)
    rep = Replayer(et, ReplayConfig(mode="comm"),
                   fabric=Fabric.build("switch", 4, mode="link")).run()
    cmp = rep.model_comparison()
    assert cmp["comm_kernels"] == rep.comm_nodes > 0
    assert cmp["modeled_s"] > 0
    assert all(k.model_time_s > 0 for k in rep.kernels
               if k.kind != "compute")


def test_routing_cache_invalidates_on_inplace_link_edit():
    g = Fabric.build("ring", 4, mode="link").graph
    r1 = g.routing()
    assert g.routing() is r1
    g.links[0].bandwidth /= 2          # degraded-link what-if
    r2 = g.routing()
    assert r2 is not r1
    assert r2.link_bw[0] == pytest.approx(r1.link_bw[0] / 2)


def test_lower_bound_guard_mirrors_collective_time():
    net = _fabric("ring", "link").network_model(CollectiveModel())
    for kind in KINDS:
        t = net.collective_time(kind, 0.0, GROUP)
        lb = net.lower_bound(kind, 0.0, GROUP)
        assert t >= lb, kind           # invariant holds at payload 0 too
        if kind != CollectiveType.BARRIER:
            assert lb == 0.0


def test_link_stats_report_busy_fractions():
    traces = [generator.moe_mixed_collectives(iters=3, ranks=4, rank=r)
              for r in range(4)]
    res = Simulator(traces, _fabric("ring", "link", 4)).run()
    assert all("busy_frac" in row for row in res.link_stats["top_links"])
    assert max(row["busy_frac"] for row in res.link_stats["top_links"]) > 0


def test_single_trace_threads_process_group_ranks():
    """Single-trace simulation must route over the process group's actual
    member NPUs, not a contiguous 0..group-1 default: (0,2,4,6) on an
    8-ring forms a symmetric 2-hop ring over all 8 links, while (0,1,2,3)
    has a 3-hop wrap-around flow over 6 links — different links, different
    time.  (Before the fix both priced identically as 0..3.)"""
    from repro.core.schema import ExecutionTrace, NodeType

    def trace_with(ranks):
        et = ExecutionTrace(rank=0, world_size=8)
        pg = et.add_process_group(list(ranks), tag="sparse")
        et.add_node(name="ar", type=NodeType.COMM_COLL,
                    comm_type=CollectiveType.ALL_REDUCE,
                    comm_group=pg.id, comm_bytes=1 << 24)
        return et

    cfg = SimConfig(congestion=False)
    sparse = simulate_single_trace(trace_with((0, 2, 4, 6)),
                                   _fabric("ring", "link"), cfg)
    dense = simulate_single_trace(trace_with((0, 1, 2, 3)),
                                  _fabric("ring", "link"), cfg)
    assert sparse.makespan_s != dense.makespan_s
    assert sparse.link_stats["links_touched"] == 8    # every ring link
    assert dense.link_stats["links_touched"] == 6     # 0..3 arc + wrap-back


# -------------------------------------------------------------- perf gate
def test_gate_regressions_flags_only_large_drops():
    from repro.perf import gate_regressions
    mk = lambda feeder_nps, sim_eps: {
        "perf_feeder": {"drain": [
            {"nodes": 10_000, "window": 64, "nodes_per_sec": feeder_nps}]},
        "perf_sim": {"scenarios": [
            {"scenario": "mixed_ar_a2a", "nodes_per_rank": 1000, "ranks": 8,
             "engine": {"events_per_sec": sim_eps}}]},
    }
    base = mk(100_000.0, 200_000.0)
    ok, report = gate_regressions(mk(90_000.0, 170_000.0), base, 0.2)
    assert ok == [] and len(report) == 2
    failures, _ = gate_regressions(mk(70_000.0, 200_000.0), base, 0.2)
    assert len(failures) == 1 and "perf_feeder" in failures[0]
    # rows missing from the baseline are skipped, not failed
    failures, report = gate_regressions(mk(1.0, 1.0), {}, 0.2)
    assert failures == [] and report == []


def test_perf_netmodel_smoke_within_budget():
    from repro.perf import perf_netmodel
    doc = perf_netmodel(scale="smoke")
    row = doc["scenarios"][0]
    assert row["analytic"]["wall_s"] > 0 and row["link"]["wall_s"] > 0
    assert row["wall_ratio"] <= 2.0       # acceptance: link within 2x
    assert doc["routing"]["pairs"] == 64 * 63
