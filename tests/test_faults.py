"""repro.faults: deterministic fault injection + the hardened sweep harness.

The acceptance gates: plan round-trips are byte-stable, same-seed MTBF
generation is deterministic, crash policies (abort vs shrink vs rejoin)
behave per contract, link_down reroutes on the routed fabric, an empty /
absent plan leaves the engine bit-identical, non-positive speed factors
fail loudly everywhere, and a SIGKILLed sweep worker loses zero rows."""
import json
import os
import signal
import warnings

import pytest

from repro.core import generator
from repro.explore import ExperimentSpec, build_report, run_sweep
from repro.explore.runner import RunCache, execute_run
from repro.faults import FaultPlan, FaultRuntime, as_fault_plan
from repro.sim import Fabric, ReferenceSimulator, SimConfig, Simulator


def dp_traces(ranks=4, steps=3, layers=4):
    return generator.generate_ranks("dp_allreduce", ranks=ranks,
                                    steps=steps, layers=layers)


def run_sim(traces, ranks, plan=None, topology="switch", mode="analytic",
            **cfg_kw):
    fabric = Fabric.build(topology, ranks, mode=mode)
    cfg = SimConfig(fault_plan=plan, **cfg_kw)
    return Simulator(traces, fabric, cfg).run()


# ------------------------------------------------------------------- plans
def test_plan_roundtrip_byte_stable():
    a = (FaultPlan(name="p", policy="shrink", collective_timeout_s=0.5)
         .rank_crash(1, t=2.0, restart_after=1.0)
         .rank_slowdown(0, t0=0.0, t1=1.0, factor=4.0)
         .link_down("npu:2", t0=0.5, t1=0.7))
    # builder order never leaks into the canonical form
    b = (FaultPlan(name="p", policy="shrink", collective_timeout_s=0.5)
         .link_down("npu:2", t0=0.5, t1=0.7)
         .rank_slowdown(0, t0=0.0, t1=1.0, factor=4.0)
         .rank_crash(1, t=2.0, restart_after=1.0))
    assert a.to_json() == b.to_json()
    assert a.plan_hash == b.plan_hash
    assert FaultPlan.from_json(a.to_json()).to_json() == a.to_json()


def test_plan_save_load_and_coercions(tmp_path):
    plan = FaultPlan(name="x").rank_slowdown(0, 0.0, 1.0, 2.0)
    p = plan.save(str(tmp_path / "plan.json"))
    assert FaultPlan.load(p).to_json() == plan.to_json()
    # as_fault_plan: None | plan | dict | path all coerce
    assert as_fault_plan(None) is None
    assert as_fault_plan(plan) is plan
    assert as_fault_plan(plan.to_dict()).to_json() == plan.to_json()
    assert as_fault_plan(p).to_json() == plan.to_json()


def test_plan_validation_rejects_bad_events():
    with pytest.raises(ValueError, match="strictly positive"):
        FaultPlan().rank_slowdown(0, 0.0, 1.0, factor=0.0)
    with pytest.raises(ValueError, match="strictly positive"):
        FaultPlan().rank_slowdown(0, 0.0, 1.0, factor=-2.0)
    with pytest.raises(ValueError, match="t1 > t0"):
        FaultPlan().link_down("l", t0=1.0, t1=1.0)
    with pytest.raises(ValueError, match="rank must be >= 0"):
        FaultPlan().rank_crash(-1, t=0.0)
    with pytest.raises(ValueError, match="unknown fault policy"):
        FaultPlan(policy="panic").validate()
    with pytest.raises(ValueError, match="unknown fault event kind"):
        FaultPlan.from_dict({"events": [{"kind": "meteor_strike"}]})


def test_mtbf_generation_same_seed_byte_identical():
    kw = dict(world_size=8, duration_s=10.0,
              crash_mtbf_s=5.0, restart_after_s=0.5,
              slowdown_mtbf_s=3.0, slowdown_factor=4.0,
              link_mtbf_s=8.0, links=["npu:0", "npu:3"])
    a = FaultPlan.generate(seed=7, **kw)
    b = FaultPlan.generate(seed=7, **kw)
    c = FaultPlan.generate(seed=8, **kw)
    assert a.to_json() == b.to_json()
    assert a.to_json() != c.to_json()
    assert not a.is_empty()


# ------------------------------------------------------------------ engine
def test_empty_plan_bit_identical_to_fault_free():
    traces = dp_traces()
    base = run_sim(traces, 4, plan=None)
    for empty in (FaultPlan(name="empty"), FaultPlan().to_dict()):
        res = run_sim(traces, 4, plan=empty)
        assert res.makespan_s == base.makespan_s
        assert res.per_rank_finish_s == base.per_rank_finish_s
        assert res.events == base.events
        assert res.collective_time_s == base.collective_time_s
        assert [vars(f) for f in res.flows] == [vars(f) for f in base.flows]
        assert res.fault_stats is None and not res.aborted
    # FaultRuntime.build is the normalization point
    assert FaultRuntime.build(None) is None
    assert FaultRuntime.build(FaultPlan()) is None


def test_slowdown_is_deterministic_and_accounted():
    traces = dp_traces()
    plan = FaultPlan(name="slow").rank_slowdown(0, 0.0, 10.0, factor=60.0)
    a = run_sim(traces, 4, plan=plan)
    b = run_sim(traces, 4, plan=plan)
    assert a.makespan_s == b.makespan_s
    assert a.fault_stats == b.fault_stats
    assert a.fault_stats["slowdown_extra_s"] > 0
    assert a.makespan_s > run_sim(traces, 4).makespan_s


def test_crash_abort_vs_shrink():
    traces = dp_traces()
    crash = dict(rank=1, t=0.0005)          # mid compute chain, no restart
    aborted = run_sim(traces, 4, plan=FaultPlan(
        name="a", policy="abort", collective_timeout_s=0.001)
        .rank_crash(**crash))
    assert aborted.aborted
    assert "timed out" in aborted.abort_reason
    assert "ABORTED" in aborted.summary()
    assert aborted.fault_stats["timeouts"] >= 1

    shrunk = run_sim(traces, 4, plan=FaultPlan(
        name="s", policy="shrink", collective_timeout_s=0.001)
        .rank_crash(**crash))
    assert not shrunk.aborted
    assert shrunk.fault_stats["collectives_shrunk"] >= 1
    assert shrunk.fault_stats["dead_ranks"] == [1]
    # the dead rank never finishes its trace; the survivors do
    assert shrunk.fault_stats["unfinished_ranks"] == [1]


def test_crash_restart_rejoins_and_finishes():
    traces = dp_traces()
    plan = (FaultPlan(name="flap", policy="shrink",
                      collective_timeout_s=0.0005)
            .rank_crash(1, t=0.0005, restart_after=0.002))
    res = run_sim(traces, 4, plan=plan)
    assert not res.aborted
    assert res.fault_stats["rejoins"] >= 1
    assert res.fault_stats["unfinished_ranks"] == []
    assert res.fault_stats["dead_ranks"] == []


def test_link_down_reroutes_on_ring():
    traces = dp_traces()
    base = run_sim(traces, 4, topology="ring", mode="link")
    res = run_sim(traces, 4, topology="ring", mode="link",
                  plan=FaultPlan(name="cut").link_down(
                      "ring0->1", t0=0.0, t1=base.makespan_s * 10))
    assert res.link_stats["faults"]["reroutes"] >= 1
    assert res.makespan_s > base.makespan_s     # detour costs hops
    # determinism under faults holds on the routed path too
    res2 = run_sim(traces, 4, topology="ring", mode="link",
                   plan=FaultPlan(name="cut").link_down(
                       "ring0->1", t0=0.0, t1=base.makespan_s * 10))
    assert res2.makespan_s == res.makespan_s


def test_link_degrade_slows_routed_traffic():
    traces = dp_traces()
    base = run_sim(traces, 4, topology="ring", mode="link")
    res = run_sim(traces, 4, topology="ring", mode="link",
                  plan=FaultPlan(name="deg").link_degrade(
                      "npu:0", t0=0.0, t1=base.makespan_s * 10, factor=8.0))
    assert res.makespan_s > base.makespan_s


def test_analytic_mode_flags_ignored_link_events():
    traces = dp_traces()
    res = run_sim(traces, 4, plan=FaultPlan(name="l").link_down(
        "npu:0", t0=0.0, t1=1.0))
    assert res.fault_stats["link_events_ignored"] is True


def test_bad_link_selector_fails_loudly():
    with pytest.raises(ValueError, match="selector"):
        run_sim(dp_traces(), 4, topology="ring", mode="link",
                plan=FaultPlan().link_down("no_such_link", 0.0, 1.0))


# ----------------------------------------------- speed-factor regressions
@pytest.mark.parametrize("bad", [0.0, -1.0, float("nan")])
def test_speed_factor_must_be_positive(bad):
    traces = dp_traces()
    fabric = Fabric.build("switch", 4)
    for engine in (Simulator, ReferenceSimulator):
        with pytest.raises(ValueError, match="strictly positive"):
            engine(traces, fabric, SimConfig(speed_factors={0: bad}))


def test_straggler_axis_rejects_non_positive_factors():
    with pytest.raises(ValueError, match="strictly positive"):
        ExperimentSpec.from_dict({
            "name": "bad", "workloads": [{"pattern": "moe_mixed"}],
            "axes": {"stragglers": [{"0": 0}]}})


# ----------------------------------------------------------------- explore
def faults_spec(**over):
    plan = (FaultPlan(name="chaos", policy="shrink",
                      collective_timeout_s=0.001)
            .rank_slowdown(0, 0.0, 10.0, factor=60.0))
    d = {
        "name": "faulty",
        "workloads": [{"pattern": "moe_mixed",
                       "args": {"mode": "mixed", "iters": 2}}],
        "axes": {"topology": ["ring", "switch"], "world_size": [4],
                 "faults": [None, plan.to_dict()]},
    }
    d.update(over)
    return ExperimentSpec.from_dict(d)


def test_empty_plan_normalizes_to_fault_free_hash():
    free = faults_spec(axes={"topology": ["ring"], "world_size": [4]})
    empty = faults_spec(axes={"topology": ["ring"], "world_size": [4],
                              "faults": [FaultPlan(name="noop").to_dict()]})
    assert [c.run_hash for c in free.expand()] \
        == [c.run_hash for c in empty.expand()]


def test_faults_axis_sweep_report_inflation(tmp_path):
    res = run_sweep(faults_spec(), jobs=1, cache_dir=str(tmp_path / "c"))
    assert res.failed == 0 and len(res.rows) == 4
    by_faults = {}
    for r in res.rows:
        by_faults.setdefault(r["faults"], []).append(r)
    assert set(by_faults) == {None, "chaos"}
    doc = build_report(res)
    entries = next(iter(doc["workloads"].values()))["ranking"]
    infl = {e["hash"]: e["fault_inflation_pct"] for e in entries}
    for e in entries:
        if e["faults"] is None:
            assert infl[e["hash"]] == 0.0
        else:
            assert infl[e["hash"]] is not None and infl[e["hash"]] > 0
    # cached replay of the faulted sweep is byte-identical
    res2 = run_sweep(faults_spec(), jobs=1, cache_dir=str(tmp_path / "c"))
    assert res2.executed == 0
    from repro.explore import report_json_bytes
    assert report_json_bytes(build_report(res2)) == report_json_bytes(doc)


def test_aborted_run_is_a_result_not_a_failure(tmp_path):
    plan = (FaultPlan(name="killer", policy="abort",
                      collective_timeout_s=0.0005)
            .rank_crash(1, t=0.0001))
    spec = ExperimentSpec.from_dict({
        "name": "abortive",
        "workloads": [{"scenario": "dp-dense"}],
        "axes": {"topology": ["ring"], "world_size": [4], "steps": [2],
                 "faults": [plan.to_dict()]},
    })
    res = run_sweep(spec, jobs=1, cache_dir=str(tmp_path / "c"))
    assert res.failed == 0 and res.aborted == 1
    row = res.rows[0]
    assert row["aborted"] and not row["ok"] and row["error"] is None
    assert "timed out" in row["abort_reason"]
    assert "1 aborted" in res.summary()
    # deterministic outcome => cacheable
    assert run_sweep(spec, jobs=1,
                     cache_dir=str(tmp_path / "c")).executed == 0
    doc = build_report(res)
    assert doc["runs"]["aborted"] == 1 and not doc["failures"]
    assert doc["aborted"][0]["abort_reason"] == row["abort_reason"]
    from repro.explore import render_markdown
    assert "Aborted (modeled fault outcomes)" in render_markdown(doc)


def test_sigkilled_worker_loses_zero_rows(tmp_path, monkeypatch):
    spec = faults_spec()
    victim = spec.expand()[0].run_hash[:12]
    marker = str(tmp_path / "chaos.marker")
    monkeypatch.setenv("REPRO_CHAOS_KILL", f"{victim}:{marker}")
    res = run_sweep(spec, jobs=2, cache_dir=str(tmp_path / "c"),
                    max_retries=2, retry_backoff_s=0.05)
    assert os.path.exists(marker)           # the kill actually fired
    assert len(res.rows) == 4 and res.failed == 0
    assert all(r["ok"] or r["aborted"] for r in res.rows)
    assert res.retries >= 1 and res.pool_rebuilds >= 1
    # the retry burned an attempt somewhere: when the pool breaks, every
    # in-flight future fails identically, so blame lands on one of them
    # (not provably the killed run) — the accounting, not the attribution,
    # is the contract
    assert any(r["attempts"] >= 2 for r in res.rows)
    assert "retried" in res.summary()
    # serial ground truth: the chaotic parallel sweep converged to it
    monkeypatch.delenv("REPRO_CHAOS_KILL")
    serial = run_sweep(spec, jobs=1)
    ks = ("hash", "makespan_s", "comm_time_total_s")
    assert ([{k: r[k] for k in ks} for r in serial.rows]
            == [{k: r[k] for k in ks} for r in res.rows])


def test_timed_out_run_becomes_failure_row_after_retries(tmp_path,
                                                         monkeypatch):
    # hang one run on every attempt: the per-run timeout tears the pool
    # down, retries it, and after max_retries emits a failure row while the
    # innocent runs still complete
    spec = faults_spec(axes={"topology": ["ring", "switch"],
                             "world_size": [4]})
    victim = spec.expand()[0].run_hash[:12]
    monkeypatch.setenv("REPRO_CHAOS_HANG", f"{victim}:60")
    res = run_sweep(spec, jobs=2, timeout_s=1.0, max_retries=1,
                    retry_backoff_s=0.05)
    assert res.failed == 1 and res.timeouts >= 1
    bad = next(r for r in res.rows if r["hash"].startswith(victim))
    assert "exceeded timeout_s" in bad["error"] and bad["attempts"] == 2
    assert all(r["ok"] for r in res.rows if not r["hash"].startswith(victim))


def test_cli_aborted_exits_zero_unless_strict(tmp_path, capsys):
    from repro.cli import main
    plan = (FaultPlan(name="killer", policy="abort",
                      collective_timeout_s=0.0005)
            .rank_crash(1, t=0.0001))
    spec_path = tmp_path / "spec.json"
    spec_path.write_text(json.dumps({
        "name": "abortive",
        "workloads": [{"scenario": "dp-dense"}],
        "axes": {"topology": ["ring"], "world_size": [4], "steps": [2],
                 "faults": [plan.to_dict()]},
    }))
    args = ["explore", str(spec_path), "--jobs", "1",
            "--cache-dir", str(tmp_path / "c")]
    assert main(args) == 0                  # modeled outcome, not an error
    out = capsys.readouterr()
    assert "1 aborted" in out.out and "failed" not in out.err
    assert main(args + ["--strict"]) == 1
    assert "aborted" in capsys.readouterr().err
    blocker = tmp_path / "file"
    blocker.write_text("x")
    cache = RunCache(str(blocker / "sub"))   # parent is a file: unwritable
    cfg = faults_spec().expand()[0]
    row = execute_run(cfg)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        cache.put(row)                       # must not raise
    assert any("run cache unwritable" in str(w.message) for w in caught)
    assert cache.get(cfg.run_hash) is None
