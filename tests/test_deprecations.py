"""Deprecation hygiene: the PR-1 shims must keep warning, and the canonical
replacements must exist where the docs point."""
import warnings

import pytest

import repro.core as core
from repro.core.generator import compute_chain


def _tiny():
    return compute_chain(n=3)


def test_core_exports_convert_shim_warns():
    with pytest.warns(DeprecationWarning, match="convert_trace"):
        out, report = core.convert(_tiny())
    assert len(out) == 3


def test_core_exports_link_shim_warns():
    host = _tiny()
    dev = _tiny()
    with pytest.warns(DeprecationWarning, match="link_traces"):
        core.link(host, dev)


def test_canonical_entry_points_exist_and_do_not_warn():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        out, _ = core.convert_trace(_tiny())
        core.link_traces(_tiny(), _tiny())
    assert len(out) == 3


def test_shims_are_the_linker_converter_functions():
    # core/__init__ re-exports the shims, not copies — one warning site
    from repro.core.converter import convert as conv_fn
    from repro.core.linker import link as link_fn
    assert core.convert is conv_fn
    assert core.link is link_fn


def test_readme_points_at_canonical_names():
    import os
    readme = os.path.join(os.path.dirname(__file__), "..", "README.md")
    text = open(readme, encoding="utf-8").read()
    assert "link_traces" in text
    assert "convert_trace" in text
