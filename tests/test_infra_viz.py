"""InfraGraph builders + trace visualizer outputs."""
from repro.core._compat import json_loads

from repro.core import generator, visualize
from repro.core.infragraph import (InfraGraph, clos_two_tier,
                                   fully_connected, ring, switch, tpu_pod_2d)
from repro.core.reconstructor import reconstruct


def test_topology_builders():
    g = ring(8, 50e9)
    assert g.num_npus == 8 and len(g.links) == 16
    g = fully_connected(4, 50e9)
    assert len(g.links) == 12
    # per-peer bandwidth sums to the end-link budget
    assert abs(sum(l.bandwidth for l in g.links if l.src == 0) - 50e9) < 1
    g = switch(4, 50e9)
    assert len(g.links) == 8 and g.link_between(0, -1) is not None
    g = clos_two_tier(32, leaf_ports=16, nic_bw=50e9, uplink_bw=100e9)
    assert g.num_npus == 32
    g = tpu_pod_2d(4, 4)
    assert g.num_npus == 16
    # torus: every chip has 4 outgoing links (2 per ring dimension)
    assert sum(1 for l in g.links if l.src == 0) == 4


def test_infragraph_json_roundtrip():
    g = ring(4, 1e9)
    g2 = InfraGraph.from_json(g.to_json())
    assert g2.num_npus == 4 and len(g2.links) == len(g.links)


def test_to_dot_truncation_deterministic_and_announced():
    et = generator.dp_allreduce_pattern(steps=4, layers=8, ranks=4)
    total = len(et)
    dot = visualize.to_dot(et, max_nodes=10)
    # deterministic selection: the 10 lowest node ids, regardless of
    # insertion order
    for nid in range(10):
        assert f'n{nid} [' in dot
    assert f"n{total - 1} [" not in dot
    # the elision is visible, not silent
    assert f"{total - 10} nodes elided" in dot
    assert dot == visualize.to_dot(et, max_nodes=10)
    # no elision marker when everything fits
    assert "elided" not in visualize.to_dot(et, max_nodes=total)


def test_visualizer_outputs():
    et = generator.dp_allreduce_pattern(steps=1, layers=3, ranks=4)
    dot = visualize.to_dot(et)
    assert dot.startswith("digraph") and "AllReduce" in dot or "comp" in dot
    timeline = reconstruct(et)
    pf = json_loads(visualize.timeline_to_perfetto(timeline))
    assert len(pf.get("traceEvents", [])) > 0
    summary = visualize.summarize(et)
    assert "nodes" in summary
