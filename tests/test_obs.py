"""repro.obs: self-tracing telemetry acceptance.

The acceptance gates: the Prometheus text exposition is byte-stable (golden
string, label escaping, cumulative histogram buckets), snapshot cadence is
deterministic under an injected clock, the 8-rank self-ingested timeline
round-trips through the repo's own Chrome parser with zero skipped and zero
unattributed events, flow-arrow endpoints resolve onto the collective lanes
recorded in the SimResult, recording never perturbs the schedule
(bit-identity), busiest-link ties are ordered by link id, and the sweep
heartbeat / metrics / CLI flags all work end-to-end."""
import io
import json

import pytest

from repro import cli
from repro.core import generator
from repro.ingest import parse_chrome_trace, standardize_chrome
from repro.obs import (TID_COLLECTIVE, TID_COMPUTE, TID_FAULT, Counter,
                       MetricsRegistry, TimelineRecorder)
from repro.sim import Fabric, SimConfig, Simulator


def moe_traces(ranks=8, iters=3):
    return [generator.moe_mixed_collectives(iters=iters, ranks=ranks, rank=r)
            for r in range(ranks)]


def run_recorded(ranks=8, iters=3, topology="switch", mode="analytic",
                 **cfg_kw):
    traces = moe_traces(ranks, iters)
    fabric = Fabric.build(topology, ranks, mode=mode)
    cfg = SimConfig(timeline=TimelineRecorder(), **cfg_kw)
    res = Simulator(traces, fabric, cfg).run()
    return res, res.timeline


# ----------------------------------------------------------------- metrics
def test_exposition_golden():
    reg = MetricsRegistry()
    reg.counter("repro_runs_total", "Total runs", labels=("status",)).inc(
        3, status="ok")
    reg.get("repro_runs_total").inc(status='we"ird\nlabel\\x')
    reg.gauge("repro_depth", "Queue depth").set(2.5)
    h = reg.histogram("repro_lat_seconds", "Latency",
                      buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    expected = (
        '# HELP repro_depth Queue depth\n'
        '# TYPE repro_depth gauge\n'
        'repro_depth 2.5\n'
        '# HELP repro_lat_seconds Latency\n'
        '# TYPE repro_lat_seconds histogram\n'
        'repro_lat_seconds_bucket{le="0.1"} 1\n'
        'repro_lat_seconds_bucket{le="1"} 2\n'
        'repro_lat_seconds_bucket{le="+Inf"} 3\n'
        'repro_lat_seconds_sum 5.55\n'
        'repro_lat_seconds_count 3\n'
        '# HELP repro_runs_total Total runs\n'
        '# TYPE repro_runs_total counter\n'
        'repro_runs_total{status="ok"} 3\n'
        'repro_runs_total{status="we\\"ird\\nlabel\\\\x"} 1\n'
    )
    assert reg.expose() == expected
    # byte-stable: rendering twice is identical
    assert reg.expose() == expected


def test_metric_misuse_fails_loudly():
    reg = MetricsRegistry()
    c = reg.counter("repro_x_total", labels=("kind",))
    with pytest.raises(ValueError):
        c.inc()                             # missing required label
    with pytest.raises(ValueError):
        c.inc(-1, kind="a")                 # counters cannot decrease
    with pytest.raises(ValueError):
        reg.gauge("repro_x_total")          # kind mismatch on re-register
    with pytest.raises(ValueError):
        reg.counter("repro_x_total", labels=("other",))   # label mismatch
    # idempotent re-registration returns the same instrument
    assert reg.counter("repro_x_total", labels=("kind",)) is c
    assert isinstance(c, Counter)


def test_snapshot_cadence_injected_clock(tmp_path):
    now = [0.0]
    reg = MetricsRegistry(clock=lambda: now[0])
    path = str(tmp_path / "m.prom")
    reg.counter("repro_ticks_total").inc()
    reg.arm_snapshots(path, interval_s=5.0)
    assert reg.maybe_snapshot()             # first call writes immediately
    now[0] = 2.0
    assert not reg.maybe_snapshot()         # inside the cadence window
    now[0] = 6.0
    assert reg.maybe_snapshot()
    text = open(path).read()
    assert "repro_ticks_total 1" in text
    assert reg.snapshot() == path           # unconditional end-of-run write
    # atomic write: no tmp litter next to the target
    assert [p.name for p in tmp_path.iterdir()] == ["m.prom"]


def _assert_parseable_exposition(text):
    """Minimal 0.0.4 grammar check: every line is HELP/TYPE or a sample."""
    for line in text.splitlines():
        if line.startswith("# HELP ") or line.startswith("# TYPE "):
            continue
        name_part, _, value = line.rpartition(" ")
        assert name_part and value, line
        float(value)                        # sample value parses
        assert name_part.startswith("repro_"), line


def test_metrics_thread_safety_hammer():
    # worker threads hammer all three instrument kinds while scrape
    # threads render: final counts must be exact (no lost updates) and
    # every mid-flight exposition must parse (no torn lines)
    import threading
    reg = MetricsRegistry()
    c = reg.counter("repro_hammer_total", "hits", labels=("who",))
    g = reg.gauge("repro_hammer_depth")
    h = reg.histogram("repro_hammer_lat_seconds", buckets=(0.1, 1.0))
    n_threads, n_iter = 8, 2000
    stop = threading.Event()
    scrapes = []

    def work(tid):
        for i in range(n_iter):
            c.inc(who=f"t{tid}")
            g.set(float(i))
            h.observe(0.05 if i % 2 else 5.0)

    def scrape():
        while not stop.is_set():
            scrapes.append(reg.expose())

    workers = [threading.Thread(target=work, args=(t,))
               for t in range(n_threads)]
    scraper = threading.Thread(target=scrape)
    scraper.start()
    for t in workers:
        t.start()
    for t in workers:
        t.join()
    stop.set()
    scraper.join()
    for tid in range(n_threads):
        assert c.value(who=f"t{tid}") == n_iter
    final = reg.expose()
    assert f"repro_hammer_lat_seconds_count {n_threads * n_iter}" in final
    assert scrapes, "scraper never ran"
    for text in scrapes[:: max(1, len(scrapes) // 50)] + [final]:
        _assert_parseable_exposition(text)


def test_merged_exposition_per_part_labels():
    from repro.obs import merged_exposition
    svc, j1, j2 = MetricsRegistry(), MetricsRegistry(), MetricsRegistry()
    svc.gauge("repro_up", "Daemon up").set(1)
    for reg, n in ((j1, 2), (j2, 5)):
        reg.counter("repro_explore_runs_total", "Sweep runs by outcome",
                    labels=("status",)).inc(n, status="ok")
    merged = merged_exposition([({}, svc), ({"job": "j1"}, j1),
                                ({"job": "j2"}, j2)])
    assert merged == (
        '# HELP repro_explore_runs_total Sweep runs by outcome\n'
        '# TYPE repro_explore_runs_total counter\n'
        'repro_explore_runs_total{status="ok",job="j1"} 2\n'
        'repro_explore_runs_total{status="ok",job="j2"} 5\n'
        '# HELP repro_up Daemon up\n'
        '# TYPE repro_up gauge\n'
        'repro_up 1\n'
    )
    _assert_parseable_exposition(merged)
    # conflicting kinds across registries are rejected loudly
    other = MetricsRegistry()
    other.gauge("repro_explore_runs_total")
    with pytest.raises(ValueError, match="refusing to merge"):
        merged_exposition([({}, j1), ({}, other)])


# ------------------------------------------------- timeline: closed loop
def test_timeline_self_ingestion_closed_loop():
    res, rec = run_recorded(ranks=8, iters=3)
    doc = rec.to_chrome()
    payload = json.dumps(doc).encode("utf-8")
    ct = parse_chrome_trace(payload)

    # our own parser must eat our own trace whole
    assert ct.skipped == 0 and ct.unmatched_be == 0
    assert ct.rank == 0 and ct.world_size == 8

    xs = [e for e in ct.events if e.ph == "X"]
    assert {e.pid for e in xs} == set(range(8))   # one pid per rank
    assert len(xs) == rec.n_spans

    # span accounting vs the SimResult: one compute span per compute node,
    # one collective span per (flow x member)
    compute = [e for e in xs if e.tid == TID_COMPUTE]
    coll = [e for e in xs if e.tid == TID_COLLECTIVE]
    n_compute_nodes = sum(
        sum(1 for n in tr if not n.is_comm) for tr in moe_traces(8, 3))
    assert len(compute) == n_compute_nodes
    assert len(coll) == sum(f.group for f in res.flows)

    # flow arrows: every id resolves, both endpoints on collective lanes,
    # start anchor ts matches a recorded flow start in the SimResult
    assert len(ct.flow_starts) == rec.n_flows > 0
    starts_ns = {round(f.start_s * 1e9) for f in res.flows}
    for fid, (spid, stid, sts) in ct.flow_starts.items():
        dpid, dtid, dts = ct.flow_ends[fid]
        assert stid == TID_COLLECTIVE and dtid == TID_COLLECTIVE
        assert sts == dts and spid != dpid
        assert sts in starts_ns

    # standardization: zero unattributed events, comm classified
    et, report = standardize_chrome(ct, source_name="self")
    assert report.unattributed_device == 0
    assert report.comm_nodes > 0
    assert len(et) == rec.n_spans

    assert rec.stats()["dropped"] == 0


def test_timeline_chkb_export_roundtrip(tmp_path):
    from repro.core.serialization import load
    _res, rec = run_recorded(ranks=4, iters=2)
    out = str(tmp_path / "timeline.chkb")
    assert rec.export(out) == out
    et = load(out)
    assert len(et) == rec.n_spans
    assert any(n.is_comm for n in et)


def test_recording_is_bit_identical():
    traces = moe_traces(4, 3)
    fabric = Fabric.build("ring", 4)
    plain = Simulator(traces, fabric, SimConfig()).run()
    rec_res = Simulator(traces, fabric,
                        SimConfig(timeline=TimelineRecorder())).run()
    met_res = Simulator(traces, fabric,
                        SimConfig(metrics=MetricsRegistry())).run()
    for other in (rec_res, met_res):
        assert other.makespan_s == plain.makespan_s
        assert other.events == plain.events
        assert other.per_rank_finish_s == plain.per_rank_finish_s
    assert plain.timeline is None and rec_res.timeline is not None


def test_link_mode_phases_and_fabric_lanes():
    res, rec = run_recorded(ranks=4, iters=2, topology="ring", mode="link")
    doc = rec.to_chrome()
    xs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    # phase sub-spans are named "<Kind>/<algo> i/n[ xrepeat]"
    phase_names = {e["name"] for e in xs if "/" in e["name"]}
    assert any(name.startswith("AllReduce/ring") for name in phase_names)
    # the fabric pseudo-process carries per-link busy lanes
    fabric_pid = rec.n_ranks
    assert any(e["pid"] == fabric_pid for e in xs)
    procs = {e["args"]["name"] for e in doc["traceEvents"]
             if e.get("ph") == "M" and e["name"] == "process_name"}
    assert "fabric" in procs


def test_fault_plan_recorded_on_timeline():
    plan = {"schema": "repro-faults/v1", "name": "obs-slow",
            "policy": "abort", "collective_timeout_s": 10.0,
            "events": [{"kind": "rank_slowdown", "rank": 1,
                        "t0": 0.0, "t1": 5.0, "factor": 3.0}]}
    _res, rec = run_recorded(ranks=4, iters=2, fault_plan=plan)
    doc = rec.to_chrome()
    faults = [e for e in doc["traceEvents"]
              if e.get("ph") == "X" and e["tid"] == TID_FAULT]
    assert any("slowdown x3" in e["name"] and e["pid"] == 1 for e in faults)


def test_top_sinks_ranked():
    _res, rec = run_recorded(ranks=4, iters=3)
    sinks = rec.top_sinks(5)
    assert 0 < len(sinks) <= 5
    totals = [s["total_s"] for s in sinks]
    assert totals == sorted(totals, reverse=True)
    assert all(s["count"] > 0 for s in sinks)


# --------------------------------------------- satellite: link-stat ties
def test_busiest_link_ties_ordered_by_link_id():
    from repro.core.infragraph import LinkLoad, RoutingTable, ring

    graph = ring(4, bandwidth=1e9)
    load = LinkLoad(RoutingTable(graph))
    # equal byte counts inserted out of id order must surface sorted by id
    for idx, b in ((3, 100.0), (1, 100.0), (2, 50.0)):
        load.bytes_by_link[idx] = b
    rows = load.top(k=3)
    assert [r["bytes"] for r in rows] == [100.0, 100.0, 50.0]
    first, second = rows[0], rows[1]
    assert (first["src"], first["dst"]) < (second["src"], second["dst"]) \
        or first["name"] < second["name"]


# --------------------------------------------------- sweep heartbeat/metrics
def obs_spec():
    from repro.explore import ExperimentSpec
    return ExperimentSpec.from_dict({
        "name": "obs-sweep",
        "workloads": [{"pattern": "moe_mixed",
                       "args": {"mode": "allreduce", "iters": 2}}],
        "axes": {"topology": ["ring", "switch"], "world_size": [4]},
    })


def test_sweep_heartbeat_stream(tmp_path):
    from repro.explore import run_sweep
    buf = io.StringIO()
    res = run_sweep(obs_spec(), jobs=1, heartbeat_s=1e-4,
                    heartbeat_stream=buf)
    assert res.failed == 0
    out = buf.getvalue()
    assert "explore[obs-sweep]: 2/2 done" in out
    assert "ETA" in out


def test_sweep_metrics_counts_outcomes(tmp_path):
    from repro.explore import run_sweep
    cache = str(tmp_path / "cache")
    reg = MetricsRegistry()
    run_sweep(obs_spec(), jobs=1, cache_dir=cache, metrics=reg)
    assert reg.get("repro_explore_runs_total").value(status="ok") == 2
    reg2 = MetricsRegistry()
    run_sweep(obs_spec(), jobs=1, cache_dir=cache, metrics=reg2)
    assert reg2.get("repro_explore_runs_total").value(status="cached") == 2
    assert reg2.get("repro_explore_queue_depth").value() == 0.0


# --------------------------------------------------------------- registry
def test_obs_export_stage_registered():
    from repro.pipeline import available_stages
    from repro.pipeline.registry import get_stage
    import repro.pipeline.builtin  # noqa: F401 — triggers registration
    assert "obs.export" in available_stages()["observe"]
    with pytest.raises(ValueError):
        get_stage("observe", "obs.export")(timeline=None, path="x.json")


# -------------------------------------------------------------------- CLI
def test_cli_sim_timeline_and_metrics(tmp_path, capsys):
    from repro.core.serialization import save
    trace = generator.moe_mixed_collectives(iters=2, ranks=4)
    src = str(tmp_path / "t.chkb")
    save(trace, src)
    tl = str(tmp_path / "tl.json")
    prom = str(tmp_path / "sim.prom")
    assert cli.main(["sim", src, "--topology", "ring", "--ranks", "4",
                     "--timeline", tl, "--metrics", prom]) == 0
    out = capsys.readouterr().out
    assert f"timeline -> {tl}" in out and f"metrics -> {prom}" in out
    doc = json.load(open(tl))
    assert doc["traceEvents"] and doc["repro_obs"]["dropped"] == 0
    text = open(prom).read()
    assert "# TYPE repro_sim_events_total counter" in text
    assert "repro_sim_makespan_seconds" in text
    # --quiet silences the progress chatter but keeps the summary
    assert cli.main(["sim", src, "--ranks", "4", "--timeline", tl,
                     "--metrics", prom, "-q"]) == 0
    out = capsys.readouterr().out
    assert "timeline ->" not in out and "metrics ->" not in out
    assert "makespan" in out


def test_cli_explore_heartbeat_metrics(tmp_path, capsys):
    spec = str(tmp_path / "study.json")
    with open(spec, "w") as fh:
        json.dump({
            "name": "cli-obs",
            "workloads": [{"pattern": "moe_mixed",
                           "args": {"mode": "allreduce", "iters": 2}}],
            "axes": {"topology": ["ring"], "world_size": [4]},
        }, fh)
    prom = str(tmp_path / "explore.prom")
    assert cli.main(["explore", spec, "--jobs", "1",
                     "--cache-dir", str(tmp_path / "cache"),
                     "--heartbeat-s", "0.0001", "--metrics", prom]) == 0
    captured = capsys.readouterr()
    assert "explore[cli-obs]" in captured.err
    assert "# TYPE repro_explore_runs_total counter" in open(prom).read()
    # --quiet silences the heartbeat
    assert cli.main(["explore", spec, "--jobs", "1",
                     "--cache-dir", str(tmp_path / "cache"),
                     "--heartbeat-s", "0.0001", "-q"]) == 0
    captured = capsys.readouterr()
    assert "explore[cli-obs]" not in captured.err


def test_cli_ingest_metrics(tmp_path):
    _res, rec = run_recorded(ranks=2, iters=2)
    tl = str(tmp_path / "tl.json")
    rec.export_chrome(tl)
    prom = str(tmp_path / "ingest.prom")
    out = str(tmp_path / "rt.chkb")
    assert cli.main(["ingest", tl, "--format", "chrome", "-o", out,
                     "--metrics", prom, "-q"]) == 0
    text = open(prom).read()
    assert 'repro_ingest_files_total{format="chrome"} 1' in text
    assert "repro_ingest_events_total" in text
