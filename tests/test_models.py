"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step asserting output shapes + no NaNs, one decode step, and
prefill-vs-decode consistency for the cache/state machinery."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import base as config_base
from repro.models import decode as decode_mod
from repro.models import model_zoo

ARCHS = config_base.names()


def _batch(cfg, B=2, S=16):
    b = {"tokens": jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) % 100,
         "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.frontend == "audio_frames":
        b["frames"] = jnp.ones((B, 8, cfg.d_model), jnp.bfloat16) * 0.1
    if cfg.frontend == "vision_patches":
        b["patches"] = jnp.ones((B, 8, cfg.d_model), jnp.bfloat16) * 0.1
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch, rng_key):
    cfg = config_base.get(arch).reduced()
    model = model_zoo.build(cfg, model_axis=1)
    params = model.init(rng_key)
    loss, metrics = model.loss_fn(params, _batch(cfg))
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss))
    assert float(metrics["tokens"]) > 0
    # one real gradient step moves the loss
    grads = jax.grad(lambda p: model.loss_fn(p, _batch(cfg))[0])(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert gnorm > 0 and not jnp.isnan(jnp.asarray(gnorm))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch, rng_key):
    cfg = config_base.get(arch).reduced()
    model = model_zoo.build(cfg, model_axis=1)
    params = model.init(rng_key)
    state = decode_mod.init_state(cfg, "smoke_dec")
    state["cache_len"] = jnp.int32(3)
    logits, state2 = decode_mod.decode_step(model, params, state,
                                            jnp.ones((2, 1), jnp.int32))
    assert logits.shape == (2, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert int(state2["cache_len"]) == 4


@pytest.mark.parametrize("arch", ["granite-8b", "mixtral-8x7b",
                                  "hymba-1.5b", "xlstm-1.3b", "glm4-9b"])
def test_decode_matches_forward(arch, rng_key):
    """Teacher-forced decode over a short prompt must reproduce the parallel
    forward's next-token logits — validates caches, RoPE offsets, SSM and
    (m/s)LSTM states end to end."""
    import dataclasses
    cfg = config_base.get(arch).reduced()
    if cfg.is_moe:
        # dropless capacity: with capacity-bounded routing the decode path
        # (groups = whole batch) and the forward path (groups = batch rows)
        # drop different tokens; dropless makes them mathematically equal
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    model = model_zoo.build(cfg, model_axis=1)
    params = model.init(rng_key)
    B, S = 2, 8
    tokens = (jax.random.randint(rng_key, (B, S), 0, 100)).astype(jnp.int32)
    full = model.logits(params, {"tokens": tokens})        # [B, S, V]

    state = decode_mod.init_state(cfg, "smoke_dec")
    got = None
    for i in range(S):
        got, state = decode_mod.decode_step(model, params, state,
                                            tokens[:, i:i + 1])
    ref = full[:, -1].astype(jnp.float32)
    err = jnp.max(jnp.abs(got - ref)) / (jnp.max(jnp.abs(ref)) + 1e-6)
    assert float(err) < 0.08, f"decode/forward divergence {float(err)}"


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_match_init(arch, rng_key):
    cfg = config_base.get(arch).reduced()
    specs, logical = model_zoo.param_specs(cfg, model_axis=1)
    params = model_zoo.init_params(cfg, rng_key, model_axis=1)
    s_leaves = jax.tree.leaves(specs)
    p_leaves = jax.tree.leaves(params)
    assert len(s_leaves) == len(p_leaves)
    for s, p in zip(s_leaves, p_leaves):
        assert s.shape == p.shape and s.dtype == p.dtype


def test_full_configs_match_assignment():
    """The exact published numbers from the assignment table."""
    c = config_base.get("mixtral-8x7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.n_experts, c.top_k) == (32, 4096, 32, 8, 14336,
                                               32000, 8, 2)
    c = config_base.get("olmoe-1b-7b")
    assert (c.n_layers, c.d_model, c.n_experts, c.top_k) == (16, 2048, 64, 8)
    c = config_base.get("hymba-1.5b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
            c.ssm_state) == (32, 1600, 25, 5, 16)
    c = config_base.get("gemma-7b")
    assert (c.head_dim_, c.d_ff, c.vocab) == (256, 24576, 256000)
    c = config_base.get("glm4-9b")
    assert (c.n_layers, c.n_kv_heads, c.vocab) == (40, 2, 151552)
    c = config_base.get("internvl2-26b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab) == (48, 6144, 48,
                                                           92553)
    c = config_base.get("xlstm-1.3b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff) == (48, 2048, 4, 0)
    c = config_base.get("seamless-m4t-large-v2")
    assert (c.n_layers, c.enc_layers, c.d_model, c.vocab) == (24, 24, 1024,
                                                              256206)
    c = config_base.get("deepseek-7b")
    assert (c.n_layers, c.n_kv_heads, c.d_ff, c.vocab) == (30, 32, 11008,
                                                           102400)
    c = config_base.get("granite-8b")
    assert (c.n_layers, c.d_ff, c.vocab) == (36, 14336, 49152)


def test_long_500k_skips_documented():
    runs = {a: config_base.get(a).runs_shape("long_500k") for a in ARCHS}
    assert runs["mixtral-8x7b"] and runs["hymba-1.5b"] and runs["xlstm-1.3b"]
    for a in ("granite-8b", "gemma-7b", "deepseek-7b", "glm4-9b",
              "internvl2-26b", "olmoe-1b-7b", "seamless-m4t-large-v2"):
        assert not runs[a]
        assert "attention" in config_base.get(a).skip_shapes["long_500k"]
