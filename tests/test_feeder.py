"""Dependency-aware feeder: ordering invariants under every policy,
windowing, elastic extension (seeded-random property tests)."""
import random

import pytest

from repro.core import ETFeeder, ExecutionTrace, NodeType, POLICIES
from repro.core.serialization import save


def random_dag(seed: int) -> ExecutionTrace:
    rng = random.Random(seed)
    n = rng.randint(1, 80)
    et = ExecutionTrace()
    for i in range(n):
        node = et.add_node(name=f"n{i}", type=NodeType.COMP,
                           start_time_micros=rng.uniform(0, 100))
        if i:
            for dep in rng.sample(range(i), k=min(i, rng.randint(0, 3))):
                node.data_deps.append(dep)
    return et


@pytest.mark.parametrize("policy", sorted(POLICIES))
@pytest.mark.parametrize("seed", range(10))
def test_feeder_never_violates_dependencies(seed, policy):
    et = random_dag(seed)
    window = random.Random(seed ^ 0xBEEF).randint(1, 16)
    feeder = ETFeeder(et, window=window, policy=policy)
    done = set()
    count = 0
    while feeder.has_pending():
        node = feeder.next_ready()
        assert node is not None, "feeder stalled on an acyclic trace"
        for d, _ in node.all_deps():
            assert d in done, f"{node.id} issued before dep {d}"
        feeder.mark_completed(node.id)
        done.add(node.id)
        count += 1
    assert count == len(et)


@pytest.mark.parametrize("seed", range(20))
def test_feeder_deterministic_under_fixed_policy(seed):
    et = random_dag(seed)
    a = ETFeeder(et, policy="start_time").drain_order()
    b = ETFeeder(et, policy="start_time").drain_order()
    assert a == b


def test_comm_priority_prefers_comm():
    et = ExecutionTrace()
    et.add_node(name="comp", type=NodeType.COMP)
    et.add_node(name="comm", type=NodeType.COMM_COLL)
    order = ETFeeder(et, policy="comm_priority").drain_order()
    assert et.nodes[order[0]].is_comm


def test_id_policy_yields_id_order_on_canonical_trace():
    # deps all point backwards (canonical/topo-numbered trace): the "id"
    # policy must reproduce exact id order — the streaming pipeline's
    # byte-identical CHKB guarantee rests on this.
    et = ExecutionTrace()
    for i in range(50):
        n = et.add_node(name=f"n{i}")
        if i >= 2:
            n.data_deps.append(i - 2)
    order = ETFeeder(et, window=7, policy="id").drain_order()
    assert order == sorted(et.nodes)


def test_feeder_from_chkb_windowed(tmp_path):
    et = ExecutionTrace()
    for i in range(200):
        n = et.add_node(name=f"n{i}")
        if i >= 3:
            n.data_deps.append(i - 3)
    p = str(tmp_path / "t.chkb")
    save(et, p, block_size=16)
    feeder = ETFeeder(p, window=8)
    order = feeder.drain_order()
    assert len(order) == 200
    pos = {n: i for i, n in enumerate(order)}
    for n in et.nodes.values():
        for d, _ in n.all_deps():
            assert pos[d] < pos[n.id]


def test_completion_before_issue_raises():
    et = ExecutionTrace()
    et.add_node(name="a")
    feeder = ETFeeder(et)
    with pytest.raises(ValueError):
        feeder.mark_completed(0)
