"""Dependency-aware feeder: ordering invariants under every policy,
windowing, elastic extension (seeded-random property tests)."""
import random

import pytest

from repro.core import ETFeeder, ExecutionTrace, NodeType, POLICIES
from repro.core.serialization import save


def random_dag(seed: int) -> ExecutionTrace:
    rng = random.Random(seed)
    n = rng.randint(1, 80)
    et = ExecutionTrace()
    for i in range(n):
        node = et.add_node(name=f"n{i}", type=NodeType.COMP,
                           start_time_micros=rng.uniform(0, 100))
        if i:
            for dep in rng.sample(range(i), k=min(i, rng.randint(0, 3))):
                node.data_deps.append(dep)
    return et


@pytest.mark.parametrize("policy", sorted(POLICIES))
@pytest.mark.parametrize("seed", range(10))
def test_feeder_never_violates_dependencies(seed, policy):
    et = random_dag(seed)
    window = random.Random(seed ^ 0xBEEF).randint(1, 16)
    feeder = ETFeeder(et, window=window, policy=policy)
    done = set()
    count = 0
    while feeder.has_pending():
        node = feeder.next_ready()
        assert node is not None, "feeder stalled on an acyclic trace"
        for d, _ in node.all_deps():
            assert d in done, f"{node.id} issued before dep {d}"
        feeder.mark_completed(node.id)
        done.add(node.id)
        count += 1
    assert count == len(et)


@pytest.mark.parametrize("seed", range(20))
def test_feeder_deterministic_under_fixed_policy(seed):
    et = random_dag(seed)
    a = ETFeeder(et, policy="start_time").drain_order()
    b = ETFeeder(et, policy="start_time").drain_order()
    assert a == b


def test_comm_priority_prefers_comm():
    et = ExecutionTrace()
    et.add_node(name="comp", type=NodeType.COMP)
    et.add_node(name="comm", type=NodeType.COMM_COLL)
    order = ETFeeder(et, policy="comm_priority").drain_order()
    assert et.nodes[order[0]].is_comm


def test_id_policy_yields_id_order_on_canonical_trace():
    # deps all point backwards (canonical/topo-numbered trace): the "id"
    # policy must reproduce exact id order — the streaming pipeline's
    # byte-identical CHKB guarantee rests on this.
    et = ExecutionTrace()
    for i in range(50):
        n = et.add_node(name=f"n{i}")
        if i >= 2:
            n.data_deps.append(i - 2)
    order = ETFeeder(et, window=7, policy="id").drain_order()
    assert order == sorted(et.nodes)


def test_feeder_from_chkb_windowed(tmp_path):
    et = ExecutionTrace()
    for i in range(200):
        n = et.add_node(name=f"n{i}")
        if i >= 3:
            n.data_deps.append(i - 3)
    p = str(tmp_path / "t.chkb")
    save(et, p, block_size=16)
    feeder = ETFeeder(p, window=8)
    order = feeder.drain_order()
    assert len(order) == 200
    pos = {n: i for i, n in enumerate(order)}
    for n in et.nodes.values():
        for d, _ in n.all_deps():
            assert pos[d] < pos[n.id]


def test_completion_before_issue_raises():
    et = ExecutionTrace()
    et.add_node(name="a")
    feeder = ETFeeder(et)
    with pytest.raises(ValueError):
        feeder.mark_completed(0)


# ------------------------------------------------- hot-path bookkeeping
def build_100k_trace() -> ExecutionTrace:
    """100k-node layered DAG: chains, fan-in, fan-out, long-range deps."""
    et = ExecutionTrace()
    n = 100_000
    for i in range(n):
        node = et.add_node(name=f"n{i}", type=NodeType.COMP)
        if i:
            node.data_deps.append(i - 1)
        if i >= 64 and i % 16 == 0:
            node.ctrl_deps.append(i - 64)       # long-range fan-in
        if i >= 1000 and i % 997 == 0:
            node.sync_deps.append(i - 1000)     # window-straddling dep
    return et


def test_feeder_dependency_invariants_100k_nodes():
    """Production-scale drain: every dep satisfied, every node fed once,
    and the O(1) bookkeeping keeps this fast enough to run in the suite."""
    et = build_100k_trace()
    feeder = ETFeeder(et, window=1024, policy="fifo")
    seen = set()
    emitted = 0
    while feeder.has_pending():
        node = feeder.next_ready()
        assert node is not None, "stalled on an acyclic 100k trace"
        assert node.id not in seen, "node fed twice"
        for d, _ in node.all_deps():
            assert d in seen, f"{node.id} issued before dep {d}"
        seen.add(node.id)
        emitted += 1
        feeder.mark_completed(node.id)
    assert emitted == len(et) == 100_000
    # bounded bookkeeping on a canonical feed: watermark absorbs everything
    assert len(feeder._completed._sparse) == 0
    assert len(feeder._nodes) <= 3 * feeder.window


def test_in_flight_counter_matches_set_semantics():
    rng = random.Random(7)
    et = random_dag(3)
    feeder = ETFeeder(et, window=4, policy="fifo")
    issued, completed = set(), set()
    while feeder.has_pending() or issued - completed:
        # randomly interleave issues and completions
        if feeder.has_pending() and (not issued - completed
                                     or rng.random() < 0.6):
            n = feeder.next_ready()
            if n is None:
                nid = rng.choice(sorted(issued - completed))
                feeder.mark_completed(nid)
                completed.add(nid)
                continue
            issued.add(n.id)
        else:
            nid = rng.choice(sorted(issued - completed))
            feeder.mark_completed(nid)
            completed.add(nid)
        assert feeder.in_flight() == len(issued - completed)
    assert feeder.in_flight() == 0


def test_has_ready_agrees_with_next_ready():
    et = random_dag(11)
    feeder = ETFeeder(et, window=3, policy="fifo")
    while feeder.has_pending():
        ready = feeder.has_ready()
        node = feeder.next_ready()
        assert (node is not None) == ready
        if node is None:
            break
        feeder.mark_completed(node.id)


def test_feeder_owns_and_closes_reader(tmp_path):
    from repro.core.serialization import ChkbReader

    et = ExecutionTrace()
    for i in range(100):
        n = et.add_node(name=f"n{i}")
        if i:
            n.data_deps.append(i - 1)
    p = str(tmp_path / "own.chkb")
    save(et, p, block_size=16)

    # path-constructed feeder owns the reader: closed on drain
    feeder = ETFeeder(p, window=8)
    reader = feeder._reader
    assert not reader.closed
    feeder.drain_order()
    assert reader.closed

    # close() / context manager close early
    with ETFeeder(p, window=8) as f2:
        r2 = f2._reader
        f2.next_ready()
    assert r2.closed

    # caller-provided reader is NOT closed by the feeder
    r3 = ChkbReader(p)
    ETFeeder(r3).drain_order()
    assert not r3.closed
    r3.close()

    # partially-consumed window stream (consumer breaks early): the
    # generator teardown must still release the owned reader
    f4 = ETFeeder(p, window=8)
    r4 = f4._reader
    gen = f4.iter_windows(8)
    next(gen)
    assert not r4.closed
    gen.close()
    assert r4.closed


def test_idset_watermark_and_stragglers():
    from repro.core.feeder import _IdSet

    s = _IdSet()
    assert 0 not in s and len(s) == 0
    for i in (0, 1, 2):
        s.add(i)
    assert s._watermark == 3 and not s._sparse
    s.add(10)                       # straggler
    assert 10 in s and 3 not in s and len(s) == 4
    for i in (4, 5, 6, 7, 8, 9):
        s.add(i)
    assert 3 not in s
    s.add(3)                        # plugs the gap; watermark sweeps sparse
    assert s._watermark == 11 and not s._sparse
    assert len(s) == 11
    s.add(5)                        # re-add below watermark: no-op
    assert len(s) == 11
    s.add(-4)                       # negative ids stay sparse, still correct
    assert -4 in s and -1 not in s
