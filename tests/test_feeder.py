"""Dependency-aware feeder: ordering invariants under every policy,
windowing, elastic extension (hypothesis property tests)."""
import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.core import ETFeeder, ExecutionTrace, NodeType, POLICIES
from repro.core.serialization import save


@st.composite
def dag(draw):
    n = draw(st.integers(1, 80))
    et = ExecutionTrace()
    for i in range(n):
        node = et.add_node(name=f"n{i}", type=NodeType.COMP,
                           start_time_micros=draw(st.floats(0, 100)))
        if i:
            for dep in draw(st.lists(st.integers(0, i - 1), max_size=3,
                                     unique=True)):
                node.data_deps.append(dep)
    return et


@given(dag(), st.sampled_from(sorted(POLICIES)), st.integers(1, 16))
@settings(max_examples=40, deadline=None)
def test_feeder_never_violates_dependencies(et, policy, window):
    feeder = ETFeeder(et, window=window, policy=policy)
    done = set()
    count = 0
    while feeder.has_pending():
        node = feeder.next_ready()
        assert node is not None, "feeder stalled on an acyclic trace"
        for d, _ in node.all_deps():
            assert d in done, f"{node.id} issued before dep {d}"
        feeder.mark_completed(node.id)
        done.add(node.id)
        count += 1
    assert count == len(et)


@given(dag())
@settings(max_examples=20, deadline=None)
def test_feeder_deterministic_under_fixed_policy(et):
    a = ETFeeder(et, policy="start_time").drain_order()
    b = ETFeeder(et, policy="start_time").drain_order()
    assert a == b


def test_comm_priority_prefers_comm():
    et = ExecutionTrace()
    et.add_node(name="comp", type=NodeType.COMP)
    et.add_node(name="comm", type=NodeType.COMM_COLL)
    order = ETFeeder(et, policy="comm_priority").drain_order()
    assert et.nodes[order[0]].is_comm


def test_feeder_from_chkb_windowed(tmp_path):
    et = ExecutionTrace()
    for i in range(200):
        n = et.add_node(name=f"n{i}")
        if i >= 3:
            n.data_deps.append(i - 3)
    p = str(tmp_path / "t.chkb")
    save(et, p, block_size=16)
    feeder = ETFeeder(p, window=8)
    order = feeder.drain_order()
    assert len(order) == 200
    pos = {n: i for i, n in enumerate(order)}
    for n in et.nodes.values():
        for d, _ in n.all_deps():
            assert pos[d] < pos[n.id]


def test_completion_before_issue_raises():
    et = ExecutionTrace()
    et.add_node(name="a")
    feeder = ETFeeder(et)
    with pytest.raises(ValueError):
        feeder.mark_completed(0)
