"""Adversarial-graph robustness for the analysis layer.

critical_path must reject cyclic traces with a clear ValueError (instead of
recursing or hanging) and handle zero-duration nodes; exposed_comm is
interval-based and must stay finite on cycles, zero durations, and non-finite
timestamps.
"""
import math

import pytest

from repro.core.analysis import critical_path, exposed_comm
from repro.core.schema import CollectiveType, ExecutionTrace, NodeType


def _cycle_trace():
    et = ExecutionTrace()
    a = et.add_node(name="a", type=NodeType.COMP, duration_micros=10.0)
    b = et.add_node(name="b", type=NodeType.COMP, duration_micros=10.0)
    a.data_deps.append(b.id)
    b.data_deps.append(a.id)
    return et


def _self_dep_trace():
    et = ExecutionTrace()
    a = et.add_node(name="a", type=NodeType.COMP, duration_micros=1.0)
    a.ctrl_deps.append(a.id)
    return et


def test_critical_path_rejects_cycle_with_clear_error():
    with pytest.raises(ValueError, match="acyclic"):
        critical_path(_cycle_trace())


def test_critical_path_rejects_self_dependency():
    with pytest.raises(ValueError, match="acyclic"):
        critical_path(_self_dep_trace())


def test_critical_path_error_mentions_repair_path():
    with pytest.raises(ValueError, match="convert"):
        critical_path(_cycle_trace())


def test_critical_path_zero_duration_nodes():
    et = ExecutionTrace()
    prev = None
    for i in range(5):
        n = et.add_node(name=f"z{i}", type=NodeType.COMP,
                        duration_micros=0.0)
        if prev is not None:
            n.data_deps.append(prev)
        prev = n.id
    cp = critical_path(et)
    assert cp.length_us == 0.0
    assert cp.node_ids  # a path still exists, it just has zero length


def test_critical_path_mixed_zero_and_positive():
    et = ExecutionTrace()
    a = et.add_node(name="a", type=NodeType.COMP, duration_micros=0.0)
    b = et.add_node(name="b", type=NodeType.COMP, duration_micros=7.0)
    b.data_deps.append(a.id)
    c = et.add_node(name="c", type=NodeType.COMP, duration_micros=0.0)
    c.data_deps.append(b.id)
    cp = critical_path(et)
    assert cp.length_us == pytest.approx(7.0)
    assert b.id in cp.node_ids
    assert cp.compute_us == pytest.approx(7.0)


def test_exposed_comm_survives_cycles():
    # interval-based: dependency edges (even cyclic) are irrelevant
    et = _cycle_trace()
    et.nodes[0].start_time_micros = 0.0
    et.nodes[1].start_time_micros = 5.0
    out = exposed_comm(et)
    assert out["makespan_us"] == pytest.approx(15.0)
    assert all(math.isfinite(v) for v in out.values())


def test_exposed_comm_zero_duration_and_nonfinite():
    et = ExecutionTrace()
    et.add_node(name="z", type=NodeType.COMP, duration_micros=0.0)
    n = et.add_node(name="nan", type=NodeType.COMP,
                    start_time_micros=float("nan"), duration_micros=5.0)
    assert n.id == 1
    inf = et.add_node(name="inf", type=NodeType.COMM_COLL,
                      comm_type=CollectiveType.ALL_REDUCE,
                      start_time_micros=float("inf"), duration_micros=5.0)
    assert inf.id == 2
    ok = et.add_node(name="ok", type=NodeType.COMP,
                     start_time_micros=1.0, duration_micros=2.0)
    assert ok.id == 3
    out = exposed_comm(et)
    assert out["compute_us"] == pytest.approx(2.0)
    assert out["comm_us"] == 0.0
    assert all(math.isfinite(v) for v in out.values())


def test_exposed_comm_empty_trace():
    out = exposed_comm(ExecutionTrace())
    assert out["makespan_us"] == 0.0
    assert all(math.isfinite(v) for v in out.values())
