"""Partition-invariance matrix for the sharded simulator (repro.sim.shard).

The contract under test: ``ShardedSimulator`` produces a ``SimResult``
bit-identical to the single-process ``Simulator`` at ANY partition count —
makespan, per-rank finishes, collective accounting, flows, utilization
timeline, link-model cache counters, and fault statistics all included.
The authority-replay design makes this hold by construction (workers only
propose event order; the parent replays it with the same pricing and the
same floating-point accumulation order as the engine), and this matrix is
the proof: analytic and link fidelities, odd rank splits, and fault plans
with cross-partition crash/restart all compared field-by-field.

Worker processes use the spawn start method, so every test here goes
through real process startup (~1s per sharded run on a small host); the
traces are kept to a few hundred nodes per rank to bound the wall clock.
"""
from __future__ import annotations

import pytest

from repro.core import generator
from repro.faults import FaultPlan
from repro.sim import (Fabric, ShardedSimulator, SimConfig, Simulator,
                       SynthSource, partition_ranks)


def norm(res):
    """Every SimResult field that the bit-identity contract covers."""
    return (res.makespan_s, tuple(res.per_rank_finish_s),
            dict(res.collective_time_s), dict(res.collective_bytes),
            tuple(res.flows), res.compute_busy_s, res.exposed_comm_s,
            tuple(res.link_util_timeline), res.events, res.link_stats,
            res.aborted, res.abort_reason, res.fault_stats)


def assert_identical(sharded, base):
    names = ("makespan", "per_rank_finish", "collective_time",
             "collective_bytes", "flows", "compute_busy", "exposed_comm",
             "link_util_timeline", "events", "link_stats", "aborted",
             "abort_reason", "fault_stats")
    for name, a, b in zip(names, norm(sharded), norm(base)):
        assert a == b, f"sharded run diverged on {name}: {a!r} != {b!r}"


def dp_traces(n=5):
    return [generator.dp_allreduce_pattern(steps=3, layers=4, ranks=n,
                                           rank=r) for r in range(n)]


def moe_traces(n=6):
    return [generator.moe_mixed_collectives(iters=3, ranks=n, rank=r)
            for r in range(n)]


def test_partition_ranks_contiguous_near_even():
    assert partition_ranks(5, 2) == [(0, 3), (3, 5)]
    assert partition_ranks(7, 3) == [(0, 3), (3, 5), (5, 7)]
    assert partition_ranks(4, 1) == [(0, 4)]
    # more parts than ranks clamps to one rank per partition
    assert partition_ranks(3, 8) == [(0, 1), (1, 2), (2, 3)]
    for n, p in ((1, 1), (64, 8), (10, 3)):
        parts = partition_ranks(n, p)
        assert parts[0][0] == 0 and parts[-1][1] == n
        assert all(a[1] == b[0] for a, b in zip(parts, parts[1:]))


def test_single_partition_takes_unsharded_fast_path():
    traces = dp_traces()
    base = Simulator(traces, Fabric.build("switch", 5), SimConfig()).run()
    sh = ShardedSimulator(traces, Fabric.build("switch", 5), SimConfig(),
                          jobs=1)
    assert_identical(sh.run(), base)
    assert sh.stats["mode"] == "unsharded"
    assert sh.stats["partitions"] == 1


@pytest.mark.parametrize("jobs", [2, 3, 8])
def test_partition_invariance_analytic(jobs):
    # jobs=2/3 are odd splits of 5 ranks; jobs=8 clamps to 1 rank/partition
    traces = dp_traces()
    base = Simulator(traces, Fabric.build("switch", 5), SimConfig()).run()
    sh = ShardedSimulator(traces, Fabric.build("switch", 5), SimConfig(),
                          jobs=jobs)
    assert_identical(sh.run(), base)
    assert sh.stats["mode"] == "sharded"
    assert len(sh.stats["partitions"]) == min(jobs, 5)


@pytest.mark.parametrize("jobs", [2, 3])
def test_partition_invariance_link_fidelity(jobs):
    # link mode: per-phase pricing, congestion, and the time-memo cache all
    # live in the authority — even the cache hit/miss counters must match
    traces = moe_traces()
    base = Simulator(traces, Fabric.build("ring", 6, mode="link"),
                     SimConfig()).run()
    sh = ShardedSimulator(traces, Fabric.build("ring", 6, mode="link"),
                          SimConfig(), jobs=jobs)
    assert_identical(sh.run(), base)


@pytest.mark.parametrize("jobs", [2, 3])
def test_partition_invariance_cross_partition_faults(jobs):
    # rank 1 dies for good; rank 3 crashes and rejoins — with jobs=2 the
    # two crashes land in different partitions, with jobs=3 the restart
    # rejoin crosses a partition boundary mid-run.  fault_stats equality is
    # part of assert_identical.
    plan = (FaultPlan(name="x", policy="shrink",
                      collective_timeout_s=0.001)
            .rank_crash(1, t=0.001)
            .rank_crash(3, t=0.002, restart_after=0.004))
    traces = dp_traces()
    base = Simulator(traces, Fabric.build("switch", 5),
                     SimConfig(fault_plan=plan)).run()
    assert base.fault_stats and base.fault_stats.get("dead_ranks") == [1]
    sh = ShardedSimulator(traces, Fabric.build("switch", 5),
                          SimConfig(fault_plan=plan), jobs=jobs)
    assert_identical(sh.run(), base)


def test_synth_source_matches_materialized_traces():
    # the streaming fleet source must price exactly like the same workload
    # handed to the engine as concrete per-rank traces
    from repro.synth import get_scenario
    src = SynthSource(profile=get_scenario("serve-decode-burst").profile(),
                      world_size=12, steps=2, ops_per_step=4, seed=7)
    traces = [src.materialize(r) for r in range(12)]
    base = Simulator(traces, Fabric.build("switch", 12), SimConfig()).run()
    sh = ShardedSimulator(src, Fabric.build("switch", 12), SimConfig(),
                          jobs=3)
    assert_identical(sh.run(), base)


def test_feeder_from_iter_matches_list_feeder():
    from repro.core.feeder import ETFeeder
    trace = generator.dp_allreduce_pattern(steps=2, layers=3, ranks=4,
                                           rank=0)
    a = ETFeeder(trace, policy="comm_priority")
    b = ETFeeder.from_iter(iter(trace), total=len(trace),
                           policy="comm_priority")
    order_a, order_b = [], []
    for f, order in ((a, order_a), (b, order_b)):
        while f.has_pending():
            node = f.next_ready()
            assert node is not None
            order.append(node.id)
            f.mark_completed(node.id)
    assert order_a == order_b


def test_timeline_rank_sampling():
    # --timeline-ranks N keeps only the N lowest rank ids' spans, the same
    # deterministic elision rule viz.to_dot uses
    from repro.obs import TimelineRecorder
    traces = dp_traces(4)
    full_cfg = SimConfig()
    full_cfg.timeline = TimelineRecorder()
    Simulator(traces, Fabric.build("switch", 4), full_cfg).run()
    lim_cfg = SimConfig()
    lim_cfg.timeline = TimelineRecorder(rank_limit=2)
    Simulator(traces, Fabric.build("switch", 4), lim_cfg).run()
    assert lim_cfg.timeline.stats()["rank_limit"] == 2

    def span_ranks(rec):
        # rank lanes use pid == rank id; fabric lanes sit at pid >= n_ranks
        return {e["pid"] for e in rec.to_chrome()["traceEvents"]
                if e["ph"] == "X" and e["pid"] < 4}

    assert span_ranks(full_cfg.timeline) == {0, 1, 2, 3}
    assert span_ranks(lim_cfg.timeline) <= {0, 1}
    assert 0 < lim_cfg.timeline.n_spans < full_cfg.timeline.n_spans
