"""Ingestion subsystem tests: parsers, correlation/standardization, golden
byte-stability, gzip transparency, CLI verb, and the closed loop
ingest -> profile -> synth -> sim.

The golden fixtures live in ``tests/data`` (regenerate with
``python tests/data/gen_ingest_fixtures.py``); the expected CHKB files are
written with ``compress=False`` so the bytes are identical in the full and
minimal (no orjson/zstandard) dependency matrices.
"""
import gzip
import io
import json
import math
import os

import pytest

from repro import cli
from repro.core.schema import CollectiveType, NodeType
from repro.core.serialization import (ChkbReader, ChkbWriter, is_chkb_path,
                                      load, save, to_chkb_bytes)
from repro.ingest import (ingest_file, parse_chrome_trace, parse_pytorch_et,
                          sniff_format, standardize_chrome,
                          standardize_pytorch_et)
from repro.ingest.correlate import classify_comm, comm_bytes_from_args, \
    parse_ranks

DATA = os.path.join(os.path.dirname(__file__), "data")
KINETO = os.path.join(DATA, "mini_kineto.json")
KINETO_GZ = os.path.join(DATA, "mini_kineto.json.gz")
PT_ET = os.path.join(DATA, "mini_pytorch_et.json")


# ===================================================================== parser
def test_parse_chrome_counts_and_metadata():
    ct = parse_chrome_trace(KINETO)
    # 2 steps x (1 B/E pair + 10 X) + 1 trailing kernel = 23 duration events
    assert len(ct.events) == 23
    assert ct.skipped == 1                       # the counter event
    assert ct.unmatched_be == 0
    assert ct.rank == 0 and ct.world_size == 2   # distributedInfo tail
    assert ct.process_names[0] == "CUDA 0"
    assert ct.thread_names[(0, 7)] == "stream 7"
    assert len(ct.flow_starts) == 2 and len(ct.flow_ends) == 2


def test_parse_chrome_gzip_is_identical():
    plain = parse_chrome_trace(KINETO)
    gzipped = parse_chrome_trace(KINETO_GZ)
    assert len(plain.events) == len(gzipped.events)
    assert [(e.name, e.ts_ns, e.dur_ns) for e in plain.events] == \
           [(e.name, e.ts_ns, e.dur_ns) for e in gzipped.events]


def test_parse_chrome_be_pairing_and_us_to_ns():
    ct = parse_chrome_trace(KINETO)
    steps = [e for e in ct.events if e.name.startswith("ProfilerStep")]
    assert len(steps) == 2
    # B at ts=1000us, E at ts=1300us -> 300us == 300000ns
    assert steps[0].dur_ns == 300_000
    gemm = next(e for e in ct.events if e.name.startswith("ampere_sgemm"))
    assert gemm.dur_ns == 40_500                 # fractional 40.5us


def test_parse_chrome_bare_array_and_truncation():
    events = [{"ph": "X", "name": "a", "ts": 1, "dur": 1}]
    ct = parse_chrome_trace(json.dumps(events).encode())
    assert len(ct.events) == 1                   # bare top-level array form
    with pytest.raises(ValueError):
        parse_chrome_trace(b'{"traceEvents": [{"ph": "X", "name": "a"')
    with pytest.raises(ValueError):
        parse_chrome_trace(b'{"no_events_here": 1}')


def test_sniff_format():
    assert sniff_format(KINETO) == "chrome"
    assert sniff_format(KINETO_GZ) == "chrome"
    assert sniff_format(PT_ET) == "pytorch_et"
    assert sniff_format(b"[{}]") == "chrome"
    with pytest.raises(ValueError):
        sniff_format(b'{"neither": 1}')


# ============================================================= classification
def test_classify_comm_patterns():
    cases = [
        ("ncclDevKernel_AllReduce_Sum_f32", NodeType.COMM_COLL,
         CollectiveType.ALL_REDUCE),
        ("ncclDevKernel_ReduceScatter_f32", NodeType.COMM_COLL,
         CollectiveType.REDUCE_SCATTER),
        ("nccl:all_gather", NodeType.COMM_COLL, CollectiveType.ALL_GATHER),
        ("ncclAllToAll", NodeType.COMM_COLL, CollectiveType.ALL_TO_ALL),
        ("c10d::broadcast_", NodeType.COMM_COLL, CollectiveType.BROADCAST),
        ("nccl:barrier", NodeType.COMM_COLL, CollectiveType.BARRIER),
        ("ncclDevKernel_SendRecv", NodeType.COMM_SEND,
         CollectiveType.POINT_TO_POINT),
        ("nccl:recv 0<-1", NodeType.COMM_RECV, CollectiveType.POINT_TO_POINT),
    ]
    for name, ntype, ctype in cases:
        got_nt, got_ct = classify_comm(name, {})
        assert (got_nt, got_ct) == (ntype, ctype), name
    assert classify_comm("aten::mm", {})[0] is None
    # the Collective name arg wins over the (absent) name pattern
    nt, ct = classify_comm("void kernel_x", {"Collective name": "allreduce"})
    assert (nt, ct) == (NodeType.COMM_COLL, CollectiveType.ALL_REDUCE)


def test_comm_bytes_and_ranks_recovery():
    assert comm_bytes_from_args(
        {"In msg nelems": 1024, "dtype": "bf16"}) == 2048
    assert comm_bytes_from_args({"In msg nelems": 16}) == 64   # default f32
    assert comm_bytes_from_args({"bytes": 99}) == 99
    assert comm_bytes_from_args({}) == 0
    assert parse_ranks("[0, 1, 3]") == (0, 1, 3)
    assert parse_ranks([4, 5]) == (4, 5)
    assert parse_ranks("0 1 2") == (0, 1, 2)
    assert parse_ranks(None) == ()


# ============================================================== standardizing
def _assert_standard(et):
    """The ingestion output contract: valid, acyclic, deps backwards."""
    assert et.is_acyclic()
    ids = set(et.nodes)
    for n in et.nodes.values():
        for d, _ in n.all_deps():
            assert d in ids
            assert d < n.id, f"forward dep {d} -> {n.id}"


def test_standardize_chrome_structure():
    et, report = ingest_file(KINETO)
    _assert_standard(et)
    assert et.rank == 0 and et.world_size == 2
    assert report.host_nodes == 16 and report.device_nodes == 7
    comm = et.comm_nodes()
    assert len(comm) == 3                        # 2 allreduce + 1 reduce_sc
    kinds = sorted(n.comm_type for n in comm)
    assert kinds == [CollectiveType.ALL_REDUCE, CollectiveType.ALL_REDUCE,
                     CollectiveType.REDUCE_SCATTER]
    # comm bytes: 262144 f32 elems = 1 MiB each; 131072 bf16 = 256 KiB
    assert sum(n.comm_bytes for n in comm) == 2 * 1048576 + 262144
    # process group recovered once (dedup) with ranks from the args
    assert len(et.process_groups) == 1
    assert et.process_groups[0].ranks == (0, 1)
    assert all(n.comm_group == 0 for n in comm)
    # memcpy events became MEM_LOAD with byte sizes
    mems = [n for n in et.nodes.values() if n.type == NodeType.MEM_LOAD]
    assert len(mems) == 2 and all(n.comm_bytes == 1048576 for n in mems)
    # device kernels carry their stream id and an anchor ctrl dep
    gemms = [n for n in et.nodes.values()
             if n.name.startswith("ampere_sgemm")]
    assert len(gemms) == 2
    for g in gemms:
        assert g.attrs["stream"] == "7" and len(g.ctrl_deps) == 1
        assert et.nodes[g.ctrl_deps[0]].name == "cudaLaunchKernel"
    # the orphan reduce-scatter hangs off the synthetic anchor
    un = [n for n in et.nodes.values() if n.name == "ingest/unattributed"]
    assert len(un) == 1 and un[0].type == NodeType.METADATA


def test_standardize_chrome_host_nesting():
    et, _ = ingest_file(KINETO)
    mm = [n for n in et.nodes.values() if n.name == "aten::mm"]
    assert len(mm) == 2
    for n in mm:   # nested inside aten::linear on the same thread
        assert [et.nodes[d].name for d in n.ctrl_deps] == ["aten::linear"]


def test_standardize_pytorch_et_and_device_splice():
    et, report = ingest_file(PT_ET)
    _assert_standard(et)
    assert report.host_nodes == 6
    assert et.metadata["source_schema"] == "1.0.2-chakra.0.0.4"
    comm = et.comm_nodes()
    assert len(comm) == 1
    assert comm[0].comm_type == CollectiveType.ALL_REDUCE
    assert comm[0].comm_bytes == 262144 * 4
    assert et.world_size == 2                    # from group ranks [0, 1]

    # device splice: rf_id == External id, group args inherited from host
    dev = {"traceEvents": [
        {"ph": "X", "name": "sgemm", "cat": "kernel", "pid": 0, "tid": 7,
         "ts": 10, "dur": 5, "args": {"External id": 103}},
        {"ph": "X", "name": "ncclDevKernel_AllReduce_f32", "cat": "kernel",
         "pid": 0, "tid": 7, "ts": 20, "dur": 9,
         "args": {"External id": 105, "In msg nelems": 262144,
                  "dtype": "float32"}}]}
    pt = parse_pytorch_et(PT_ET)
    devct = parse_chrome_trace(json.dumps(dev).encode())
    et2, rep2 = standardize_pytorch_et(pt, device=devct)
    _assert_standard(et2)
    assert rep2.ext_resolved == 2 and rep2.unattributed_device == 0
    kern = next(n for n in et2.nodes.values() if n.name == "sgemm")
    assert et2.nodes[kern.ctrl_deps[0]].name == "aten::mm"
    nccl = next(n for n in et2.nodes.values()
                if n.name.startswith("ncclDevKernel"))
    # host nccl:all_reduce stays COMP (device side carries the comm op) and
    # the kernel inherits the host op's process-group args
    host_comm = next(n for n in et2.nodes.values()
                     if n.name == "nccl:all_reduce")
    assert host_comm.type == NodeType.COMP
    assert nccl.type == NodeType.COMM_COLL and nccl.comm_group >= 0
    assert et2.process_groups[nccl.comm_group].ranks == (0, 1)


def test_standardize_rank_world_size_overrides():
    et, _ = ingest_file(KINETO, rank=1, world_size=4)
    assert et.rank == 1 and et.world_size == 4


# ==================================================================== goldens
@pytest.mark.parametrize("src,golden", [
    (KINETO, "mini_kineto.expected.chkb"),
    (PT_ET, "mini_pytorch_et.expected.chkb"),
])
def test_golden_chkb_byte_stable(src, golden):
    et, _ = ingest_file(src)
    got = to_chkb_bytes(et, compress=False)
    with open(os.path.join(DATA, golden), "rb") as fh:
        assert got == fh.read()


def test_ingested_roundtrips_through_chkb(tmp_path):
    et, _ = ingest_file(KINETO)
    path = str(tmp_path / "t.chkb")
    save(et, path)
    back = load(path)
    assert back.to_dict() == et.to_dict()
    _assert_standard(back)


# ================================================================ chkb gzip
def test_chkb_gz_roundtrip(tmp_path):
    et, _ = ingest_file(KINETO)
    plain = str(tmp_path / "t.chkb")
    gz = str(tmp_path / "t.chkb.gz")
    save(et, plain)
    save(et, gz)
    # the gzip payload is exactly the plain file (deterministic mtime=0)
    with open(gz, "rb") as fh:
        assert gzip.decompress(fh.read()) == open(plain, "rb").read()
    assert load(gz).to_dict() == et.to_dict()
    # the windowed reader sniffs the magic and keeps its block API
    with ChkbReader(gz) as r:
        assert r.version == 4
        assert r.node_count == len(et)
        assert [n.id for n in r.iter_nodes()] == sorted(et.nodes)


def test_chkb_gz_writer_and_suffix_helper(tmp_path):
    et, _ = ingest_file(PT_ET)
    w = ChkbWriter(et.skeleton())
    w.add_nodes(et.sorted_nodes())
    out = w.write(str(tmp_path / "w.chkb.gz"))
    assert load(out).to_dict() == et.to_dict()
    assert is_chkb_path("a.chkb") and is_chkb_path("a.chkb.gz")
    assert not is_chkb_path("a.json") and not is_chkb_path("a.gz")


# ============================================================ synth guards
def test_value_accumulator_clamps_pathological_values():
    from repro.synth.sampler import ValueAccumulator
    acc = ValueAccumulator()
    for v in (float("nan"), float("inf"), float("-inf"), -5.0, 0.0, 2.0):
        acc.add(v)
    d = acc.dist()
    assert d.kind == "discrete"
    assert all(math.isfinite(v) and v >= 0 for v in d.values)
    assert d.total() == 6


def test_profile_of_ingested_trace_is_finite_and_canonical():
    from repro.synth import ProfileBuilder
    et, _ = ingest_file(KINETO)   # has zero-duration + no-comm_bytes nodes
    profile = ProfileBuilder().add_trace(et).finish()
    payload = profile.to_json_bytes()
    assert b"NaN" not in payload and b"Infinity" not in payload
    doc = json.loads(payload)     # strict: NaN would raise in most parsers
    for dist in list(doc["duration_us"].values()) + \
            list(doc["comm_bytes"].values()):
        for v in dist.get("values", []):
            assert math.isfinite(v) and v >= 0
    # byte-stable: profiling the same trace twice is identical
    assert ProfileBuilder().add_trace(et).finish().to_json_bytes() == payload


# ================================================================== pipeline
def test_ingest_stage_in_pipeline():
    from repro.pipeline import Pipeline
    stats = (Pipeline.from_source("ingest.chrome", path=KINETO)
             .sink("analyze").run())
    assert stats["nodes"] == 24 and stats["world_size"] == 2
    assert "AllReduce" in stats["comm_summary"]


def test_closed_loop_ingest_profile_synth_sim(tmp_path):
    """The paper's interoperability loop: a foreign trace drives profile ->
    synthesize -> simulate with a valid, rendezvous-consistent result."""
    from repro.pipeline import Pipeline
    from repro.synth import ProfileBuilder, synthesize
    et, _ = ingest_file(KINETO)
    profile = ProfileBuilder().add_trace(et).finish()
    man = synthesize(profile, str(tmp_path / "synth"),
                     world_size=profile.world_size, steps=2, seed=0)
    assert man["total_nodes"] > 0 and len(man["paths"]) == 2
    for p in man["paths"]:
        _assert_standard(load(p))
    res = (Pipeline.from_source("load", man["paths"][0])
           .sink("sim", topology="ring", ranks=len(man["paths"]),
                 extra_traces=man["paths"][1:]).run())
    assert res.makespan_s > 0


# ======================================================================= CLI
def test_cli_ingest_single(tmp_path, capsys):
    out = str(tmp_path / "t.chkb")
    assert cli.main(["ingest", KINETO, "-o", out]) == 0
    assert "ingested [chrome]" in capsys.readouterr().out
    _assert_standard(load(out))


def test_cli_ingest_gz_input_gz_output(tmp_path):
    out = str(tmp_path / "t.chkb.gz")
    assert cli.main(["ingest", KINETO_GZ, "--format", "chrome",
                     "-o", out]) == 0
    assert load(out).world_size == 2


def test_cli_ingest_multi_rank(tmp_path, capsys):
    # one file per rank; ranks inferred from the filenames
    for r in (0, 1):
        doc = json.load(open(KINETO))
        doc["distributedInfo"]["rank"] = r
        with open(tmp_path / f"trace_rank{r}.json", "w") as fh:
            json.dump(doc, fh)
    out = str(tmp_path / "job.chkb")
    assert cli.main(["ingest", str(tmp_path / "trace_rank0.json"),
                     str(tmp_path / "trace_rank1.json"), "-o", out]) == 0
    for r in (0, 1):
        et = load(str(tmp_path / f"job.rank{r:05d}.chkb"))
        assert et.rank == r and et.world_size == 2
    assert "2 rank(s)" in capsys.readouterr().out


def test_cli_ingest_rank_conflict_and_rank_map(tmp_path):
    # both filenames infer rank 1 -> ambiguous without --rank-map
    for name in ("a_rank1.json", "b_rank1.json"):
        with open(tmp_path / name, "w") as fh:
            json.dump(json.load(open(KINETO)), fh)
    args = [str(tmp_path / "a_rank1.json"), str(tmp_path / "b_rank1.json"),
            "-o", str(tmp_path / "o.chkb")]
    with pytest.raises(SystemExit):
        cli.main(["ingest"] + args)
    assert cli.main(["ingest"] + args
                    + ["--rank-map", "b_rank1.json=3"]) == 0
    et = load(str(tmp_path / "o.rank00003.chkb"))
    assert et.rank == 3 and et.world_size == 4


def test_cli_ingest_pytorch_et_with_device(tmp_path):
    dev = {"traceEvents": [
        {"ph": "X", "name": "sgemm", "cat": "kernel", "pid": 0, "tid": 7,
         "ts": 10, "dur": 5, "args": {"External id": 103}}]}
    devp = str(tmp_path / "dev.json")
    json.dump(dev, open(devp, "w"))
    out = str(tmp_path / "pt.chkb")
    assert cli.main(["ingest", PT_ET, "--device", devp, "-o", out]) == 0
    et = load(out)
    assert any(n.name == "sgemm" for n in et.nodes.values())


def test_cli_profile_sim_closed_loop(tmp_path, capsys):
    out = str(tmp_path / "t.chkb")
    assert cli.main(["ingest", KINETO, "-o", out]) == 0
    assert cli.main(["profile", out, "--sim"]) == 0
    assert "makespan" in capsys.readouterr().out


def test_cli_stages_kind_filter(capsys):
    assert cli.main(["stages", "--kind", "source"]) == 0
    out = capsys.readouterr().out
    assert "ingest.chrome" in out and "ingest.pytorch_et" in out
    assert "\nsink:" not in out
    # full listing is kind-grouped in canonical order
    assert cli.main(["stages"]) == 0
    out = capsys.readouterr().out
    order = [ln[:-1] for ln in out.splitlines()
             if ln.endswith(":") and not ln.startswith(" ")]
    assert order == [k for k in ("source", "pass", "sink", "benchmark",
                                 "experiment", "observe", "service") if k in order]
    with pytest.raises(SystemExit):
        cli.main(["stages", "--kind", "nope"])
