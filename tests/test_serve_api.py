"""repro.serve_api: live benchmark service acceptance.

The gates: a daemon on an ephemeral port accepts an ExperimentSpec over
HTTP, streams well-formed ordered SSE progress, serves a report
byte-identical to the offline CLI, exposes per-job outcome counters on one
merged /metrics, runs a repeat submission entirely from the shared cache
(zero new simulations), survives a restart with finished jobs intact, and
the progress events share one accounting path with the stderr heartbeat."""
import io
import json
import time
import urllib.error
import urllib.request

import pytest

from repro.explore import (ExperimentSpec, build_report, report_json_bytes,
                           run_sweep)
from repro.serve_api import BenchmarkService, EventBus, JobStore

SPEC = {
    "name": "serve-mini",
    "workloads": [{"pattern": "moe_mixed",
                   "args": {"mode": "allreduce", "iters": 2}}],
    "axes": {"topology": ["ring", "switch", "clos"], "world_size": [4]},
}


# ------------------------------------------------------------------ helpers
def http_get(base, path):
    with urllib.request.urlopen(base + path) as r:
        return r.status, r.read()


def http_post(base, path, obj):
    req = urllib.request.Request(
        base + path, data=json.dumps(obj).encode(), method="POST",
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req) as r:
        return r.status, json.loads(r.read())


def wait_terminal(base, jid, timeout_s=60.0):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        _, body = http_get(base, f"/api/v1/sweeps/{jid}")
        st = json.loads(body)
        if st["state"] in ("done", "failed"):
            return st
        time.sleep(0.02)
    raise AssertionError(f"job {jid} did not finish: {st}")


@pytest.fixture()
def service(tmp_path):
    svc = BenchmarkService(port=0, state_dir=str(tmp_path / "state"),
                           cache_dir=str(tmp_path / "cache"), workers=2,
                           quiet=True)
    host, port = svc.start()
    yield svc, f"http://{host}:{port}", tmp_path
    svc.stop(drain=True, timeout_s=30)


# -------------------------------------------------------------- end to end
def test_service_end_to_end(service):
    svc, base, tmp_path = service
    status, sub = http_post(base, "/api/v1/sweeps", SPEC)
    assert status == 202 and sub["state"] == "queued"
    jid = sub["id"]
    st = wait_terminal(base, jid)
    assert st["state"] == "done", st
    assert st["progress"]["done"] == st["progress"]["total"] == 3
    assert st["progress"]["eta_s"] == 0.0

    # report byte-identity vs the offline path (fresh cache: same spec,
    # independent execution — the determinism contract, not cache reuse)
    _, served = http_get(base, f"/api/v1/sweeps/{jid}/report")
    res = run_sweep(ExperimentSpec.from_dict(SPEC),
                    cache_dir=str(tmp_path / "offline_cache"))
    assert served == report_json_bytes(build_report(res))

    # markdown view renders the same doc
    _, md = http_get(base, f"/api/v1/sweeps/{jid}/report?format=md")
    assert md.decode().startswith("# Co-design sweep report: serve-mini")

    # SSE: well-formed, ordered ids, bracketed by sweep_started/finished
    _, raw = http_get(base, f"/api/v1/sweeps/{jid}/events")
    events, ids = [], []
    for block in raw.decode().strip().split("\n\n"):
        lines = block.splitlines()
        assert lines[0].startswith("id: ")
        assert lines[1].startswith("event: ")
        assert lines[2].startswith("data: ")
        ids.append(int(lines[0][4:]))
        events.append(json.loads(lines[2][6:]))
    assert ids == list(range(1, len(ids) + 1))
    kinds = [e["event"] for e in events]
    assert kinds[0] == "sweep_started" and kinds[-1] == "sweep_finished"
    assert kinds.count("run_finished") == 3
    # ?after= replay resumes mid-stream: exactly the final event remains
    _, tail = http_get(base, f"/api/v1/sweeps/{jid}/events?after={ids[-2]}")
    blocks = tail.decode().strip().split("\n\n")
    assert len(blocks) == 1 and blocks[0].startswith(f"id: {ids[-1]}\n")

    # /metrics: service counters + the job's sweep registry under job=""
    _, m = http_get(base, "/metrics")
    text = m.decode()
    assert 'repro_sweep_runs_total{status="ok"} 3' in text
    assert 'repro_sweep_jobs_total{event="completed"} 1' in text
    assert f'repro_explore_runs_total{{status="ok",job="{jid}"}} 3' in text
    assert "repro_build_info{" in text
    assert "repro_uptime_seconds" in text

    # second identical submission: served fully from the shared cache
    _, sub2 = http_post(base, "/api/v1/sweeps", SPEC)
    st2 = wait_terminal(base, sub2["id"])
    assert st2["state"] == "done"
    assert st2["progress"]["cached"] == 3          # zero new simulations
    _, served2 = http_get(base, f"/api/v1/sweeps/{sub2['id']}/report")
    assert served2 == served
    _, m2 = http_get(base, "/metrics")
    assert 'repro_sweep_runs_total{status="cached"} 3' in m2.decode()

    # listing shows both jobs
    _, listing = http_get(base, "/api/v1/sweeps")
    jobs = json.loads(listing)["jobs"]
    assert [j["id"] for j in jobs] == [jid, sub2["id"]]


def test_restart_serves_finished_reports(tmp_path):
    state = str(tmp_path / "state")
    svc = BenchmarkService(port=0, state_dir=state,
                           cache_dir=str(tmp_path / "cache"), quiet=True)
    host, port = svc.start()
    base = f"http://{host}:{port}"
    _, sub = http_post(base, "/api/v1/sweeps", SPEC)
    wait_terminal(base, sub["id"])
    _, served = http_get(base, f"/api/v1/sweeps/{sub['id']}/report")
    # simulate an unclean exit mid-sweep: a queued record the old daemon
    # never ran (written behind the running server's back)
    svc.store.create(SPEC, "serve-mini", "x" * 64)
    svc.stop(drain=True, timeout_s=30)

    svc2 = BenchmarkService(port=0, state_dir=state,
                            cache_dir=str(tmp_path / "cache"), quiet=True)
    host2, port2 = svc2.start()
    base2 = f"http://{host2}:{port2}"
    try:
        # finished report: byte-identical across the restart
        _, served2 = http_get(base2, f"/api/v1/sweeps/{sub['id']}/report")
        assert served2 == served
        # interrupted job: failed loudly, report answers 409
        assert svc2.recovered == ["j00002"]
        _, body = http_get(base2, "/api/v1/sweeps/j00002")
        st = json.loads(body)
        assert st["state"] == "failed" and "restarted" in st["error"]
        with pytest.raises(urllib.error.HTTPError) as ei:
            http_get(base2, "/api/v1/sweeps/j00002/report")
        assert ei.value.code == 409
    finally:
        svc2.stop(drain=True, timeout_s=30)


def test_http_error_paths(service):
    _, base, _ = service
    _, sub = http_post(base, "/api/v1/sweeps", SPEC)
    for path, code in [("/api/v1/sweeps/nope", 404),
                       ("/nope", 404),
                       (f"/api/v1/sweeps/{sub['id']}/nope", 404),
                       (f"/api/v1/sweeps/{sub['id']}/events?after=x", 400)]:
        with pytest.raises(urllib.error.HTTPError) as ei:
            http_get(base, path)
        assert ei.value.code == code, path
    with pytest.raises(urllib.error.HTTPError) as ei:
        http_post(base, "/api/v1/sweeps", {"workloads": []})
    assert ei.value.code == 400
    assert "invalid spec" in json.loads(ei.value.read())["error"]
    _, body = http_get(base, "/healthz")
    assert json.loads(body)["ok"] is True


# ------------------------------------- one accounting path (heartbeat/SSE)
def test_progress_events_agree_with_heartbeat(tmp_path):
    events = []
    buf = io.StringIO()
    res = run_sweep(ExperimentSpec.from_dict(SPEC),
                    cache_dir=str(tmp_path / "cache"),
                    heartbeat_s=1e-4, heartbeat_stream=buf,
                    on_event=events.append)
    last = events[-1]
    assert last["event"] == "sweep_finished"
    p = last["progress"]
    assert p["done"] == p["total"] == 3
    assert [e["event"] for e in events].count("run_finished") == 3
    # the heartbeat line renders the same numbers the events carry
    final_line = buf.getvalue().strip().splitlines()[-1]
    assert (f"explore[serve-mini]: {p['done']}/{p['total']} done "
            f"({p['cached']} cached, {p['failed']} failed, "
            f"{p['aborted']} aborted)") in final_line
    assert res.retries == p["retries"]
    assert res.executed + res.cached == p["done"]
    # every event carries a monotonically non-decreasing done counter
    dones = [e["progress"]["done"] for e in events]
    assert dones == sorted(dones)


# ------------------------------------------------------------------- units
def test_event_bus_replay_and_close():
    bus = EventBus()
    bus.register("j1")
    assert bus.publish("j1", {"event": "a"}) == 1
    assert bus.publish("j1", {"event": "b"}) == 2
    bus.close("j1")
    assert [(s, e["event"]) for s, e in bus.stream("j1")] == \
        [(1, "a"), (2, "b")]
    assert [s for s, _ in bus.stream("j1", after=1)] == [2]
    with pytest.raises(ValueError):
        bus.publish("j1", {"event": "c"})       # closed stream
    assert list(bus.stream("unknown")) == []    # unknown job: empty stream


def test_job_store_persistence_roundtrip(tmp_path):
    store = JobStore(str(tmp_path))
    job = store.create({"workloads": []}, "x", "h" * 64)
    store.update(job["id"], persist=True, state="done",
                 report={"schema": "r"}, summary="s")
    # a fresh store (new daemon) sees the terminal record verbatim
    store2 = JobStore(str(tmp_path))
    assert store2.recover() == []
    got = store2.get(job["id"])
    assert got["state"] == "done" and got["report"] == {"schema": "r"}
    # ids keep counting after reload — no reuse across restarts
    assert store2.create({}, "y", "h" * 64)["id"] == "j00002"
    # atomic persistence: no tmp litter
    assert all(not p.name.endswith(".tmp")
               for p in (tmp_path / "jobs").iterdir())


def test_service_stage_registered():
    from repro.pipeline.registry import available_stages, stage_doc
    import repro.pipeline  # noqa: F401 — registers builtins
    assert "serve.api" in available_stages()["service"]
    assert "daemon" in stage_doc("service", "serve.api")
