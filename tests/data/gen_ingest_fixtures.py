"""Regenerate the ingestion golden fixtures (checked-in; run manually).

    PYTHONPATH=src python tests/data/gen_ingest_fixtures.py

Produces, in this directory:

* ``mini_kineto.json``          — a miniature Kineto/Chrome trace: two
  training steps of cpu_ops + runtime launches + device kernels (GeMM,
  NCCL allreduce + reduce_scatter with full comm args, memcpy with an
  ``ac2g`` flow arrow), B/E pairs, metadata and counter events, and a
  ``distributedInfo`` tail — every event shape the parser handles.
* ``mini_kineto.json.gz``       — the same bytes, gzip with mtime=0.
* ``mini_pytorch_et.json``      — a miniature PyTorch-ET node list with
  rf_id attrs and a comm op.
* ``mini_kineto.expected.chkb`` / ``mini_pytorch_et.expected.chkb`` —
  byte-stable standardized output, written with ``compress=False`` so the
  bytes are identical whether or not orjson/zstandard are installed.

Everything here is hand-pinned (no timestamps, no randomness): the goldens
must be byte-identical on every machine and in every dependency matrix.
"""
from __future__ import annotations

import gzip
import io
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))


def mini_kineto() -> dict:
    ev = []
    # ------------------------------------------------ metadata events
    ev.append({"ph": "M", "name": "process_name", "pid": 4001, "tid": 0,
               "args": {"name": "python"}})
    ev.append({"ph": "M", "name": "process_name", "pid": 0, "tid": 0,
               "args": {"name": "CUDA 0"}})
    ev.append({"ph": "M", "name": "thread_name", "pid": 0, "tid": 7,
               "args": {"name": "stream 7"}})
    ev.append({"ph": "M", "name": "thread_name", "pid": 0, "tid": 20,
               "args": {"name": "stream 20 (memcpy)"}})

    def step(base_ts: int, ext0: int, corr0: int) -> None:
        t = base_ts
        # profiler step annotation wraps the whole step (B/E pair)
        ev.append({"ph": "B", "name": f"ProfilerStep#{ext0 // 100}",
                   "cat": "user_annotation", "pid": 4001, "tid": 2, "ts": t})
        # host op: linear -> nested mm -> runtime launch
        ev.append({"ph": "X", "name": "aten::linear", "cat": "cpu_op",
                   "pid": 4001, "tid": 2, "ts": t + 10, "dur": 120,
                   "args": {"External id": ext0 + 1}})
        ev.append({"ph": "X", "name": "aten::mm", "cat": "cpu_op",
                   "pid": 4001, "tid": 2, "ts": t + 20, "dur": 80,
                   "args": {"External id": ext0 + 2}})
        ev.append({"ph": "X", "name": "cudaLaunchKernel",
                   "cat": "cuda_runtime", "pid": 4001, "tid": 2,
                   "ts": t + 60, "dur": 8,
                   "args": {"External id": ext0 + 2,
                            "correlation": corr0 + 1}})
        # GeMM kernel on stream 7, correlation-matched
        ev.append({"ph": "X",
                   "name": "ampere_sgemm_128x64_tn", "cat": "kernel",
                   "pid": 0, "tid": 7, "ts": t + 90, "dur": 40.5,
                   "args": {"External id": ext0 + 2,
                            "correlation": corr0 + 1}})
        # collective: host op -> launch -> nccl kernel with full comm args
        ev.append({"ph": "X", "name": "c10d::allreduce_", "cat": "cpu_op",
                   "pid": 4001, "tid": 2, "ts": t + 140, "dur": 30,
                   "args": {"External id": ext0 + 3}})
        ev.append({"ph": "X", "name": "cudaLaunchKernel",
                   "cat": "cuda_runtime", "pid": 4001, "tid": 2,
                   "ts": t + 150, "dur": 6,
                   "args": {"External id": ext0 + 3,
                            "correlation": corr0 + 2}})
        ev.append({"ph": "X",
                   "name": "ncclDevKernel_AllReduce_Sum_f32_RING_LL",
                   "cat": "kernel", "pid": 0, "tid": 7,
                   "ts": t + 170, "dur": 95,
                   "args": {"External id": ext0 + 3,
                            "correlation": corr0 + 2,
                            "In msg nelems": 262144,
                            "Out msg nelems": 262144,
                            "dtype": "float32", "Group size": 2,
                            "Process Group Ranks": "[0, 1]",
                            "Process Group Name": "0",
                            "Collective name": "allreduce"}})
        # memcpy attributed through an ac2g flow arrow (no correlation)
        ev.append({"ph": "X", "name": "cudaMemcpyAsync",
                   "cat": "cuda_runtime", "pid": 4001, "tid": 2,
                   "ts": t + 210, "dur": 5, "args": {}})
        ev.append({"ph": "s", "cat": "ac2g", "id": corr0 + 3,
                   "pid": 4001, "tid": 2, "ts": t + 210})
        ev.append({"ph": "X", "name": "Memcpy HtoD (Pageable -> Device)",
                   "cat": "gpu_memcpy", "pid": 0, "tid": 20,
                   "ts": t + 230, "dur": 12,
                   "args": {"bytes": 1048576}})
        ev.append({"ph": "f", "cat": "ac2g", "id": corr0 + 3, "bp": "e",
                   "pid": 0, "tid": 20, "ts": t + 230})
        # a zero-duration instant-ish op (profile-robustness fixture: the
        # synth guard must not produce NaN dists from it)
        ev.append({"ph": "X", "name": "aten::empty", "cat": "cpu_op",
                   "pid": 4001, "tid": 2, "ts": t + 250, "dur": 0,
                   "args": {"External id": ext0 + 4}})
        ev.append({"ph": "E", "cat": "user_annotation",
                   "pid": 4001, "tid": 2, "ts": t + 300})

    step(1000, 100, 500)
    step(2000, 200, 600)
    # a reduce-scatter kernel with no host anchor (unattributed path) and
    # name-pattern comm classification (no "Collective name" arg)
    ev.append({"ph": "X",
               "name": "ncclDevKernel_ReduceScatter_Sum_bf16_RING_LL",
               "cat": "kernel", "pid": 0, "tid": 7, "ts": 3000, "dur": 60,
               "args": {"In msg nelems": 131072, "dtype": "bf16",
                        "Group size": 2,
                        "Process Group Ranks": "[0, 1]",
                        "Process Group Name": "0"}})
    # counter event: counted as skipped
    ev.append({"ph": "C", "name": "Memory", "pid": 4001, "tid": 0,
               "ts": 3100, "args": {"allocated": 1024}})
    return {
        "schemaVersion": 1,
        "traceEvents": ev,
        "traceName": "mini_kineto",
        "distributedInfo": {"backend": "nccl", "rank": 0, "world_size": 2},
    }


def mini_pytorch_et() -> dict:
    nodes = [
        {"id": 1, "name": "[pytorch|profiler|execution_trace|process]",
         "ctrl_deps": None, "inputs": {"values": []},
         "attrs": [{"name": "rf_id", "type": "uint64", "value": 0}]},
        {"id": 2, "name": "aten::linear", "ctrl_deps": 1, "dur": 120,
         "attrs": [{"name": "rf_id", "type": "uint64", "value": 102}]},
        {"id": 3, "name": "aten::mm", "ctrl_deps": 2, "dur": 80,
         "attrs": [{"name": "rf_id", "type": "uint64", "value": 103}]},
        {"id": 4, "name": "aten::relu", "ctrl_deps": 2, "dur": 15,
         "attrs": [{"name": "rf_id", "type": "uint64", "value": 104}]},
        {"id": 5, "name": "nccl:all_reduce", "ctrl_deps": 1, "dur": 95,
         "attrs": [{"name": "rf_id", "type": "uint64", "value": 105},
                   {"name": "In msg nelems", "type": "uint64",
                    "value": 262144},
                   {"name": "dtype", "type": "string", "value": "float32"},
                   {"name": "Process Group Ranks", "type": "string",
                    "value": "[0, 1]"},
                   {"name": "Process Group Name", "type": "string",
                    "value": "0"}]},
        # zero-duration node + list-valued ctrl_deps (tolerant-parse paths)
        {"id": 6, "name": "aten::empty", "ctrl_deps": [1], "dur": 0,
         "attrs": [{"name": "rf_id", "type": "uint64", "value": 106}]},
    ]
    return {"schema": "1.0.2-chakra.0.0.4", "pid": 4001, "time": "pinned",
            "start_ts": 0, "nodes": nodes}


def main() -> None:
    from repro.core.serialization import to_chkb_bytes
    from repro.ingest import ingest_file

    kineto_path = os.path.join(HERE, "mini_kineto.json")
    payload = (json.dumps(mini_kineto(), indent=1, sort_keys=False)
               + "\n").encode("utf-8")
    with open(kineto_path, "wb") as fh:
        fh.write(payload)
    buf = io.BytesIO()
    with gzip.GzipFile(fileobj=buf, mode="wb", mtime=0) as gz:
        gz.write(payload)
    with open(kineto_path + ".gz", "wb") as fh:
        fh.write(buf.getvalue())

    pt_path = os.path.join(HERE, "mini_pytorch_et.json")
    with open(pt_path, "wb") as fh:
        fh.write((json.dumps(mini_pytorch_et(), indent=1) + "\n")
                 .encode("utf-8"))

    # goldens: compress=False so bytes match in every dependency matrix
    # (the default codec differs between zstd and stdlib-zlib environments)
    for src, name in ((kineto_path, "mini_kineto.expected.chkb"),
                      (pt_path, "mini_pytorch_et.expected.chkb")):
        et, report = ingest_file(src)
        with open(os.path.join(HERE, name), "wb") as fh:
            fh.write(to_chkb_bytes(et, compress=False))
        print(f"{name}: {report.summary()}")


if __name__ == "__main__":
    main()
