"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.decode_attention import decode_attention
from repro.kernels.decode_attention import reference as decode_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention import reference as flash_ref
from repro.kernels.mlstm import mlstm_chunkwise
from repro.kernels.mlstm import reference as mlstm_ref
from repro.kernels.rmsnorm import rms_norm
from repro.kernels.rmsnorm import reference as rms_ref
from repro.kernels.ssm_scan import reference as ssm_ref
from repro.kernels.ssm_scan import ssm_scan

TOL = {jnp.float32: 5e-5, jnp.bfloat16: 5e-2}


def _tol(dtype):
    return TOL.get(dtype, 5e-5)


@pytest.mark.parametrize("B,S,H,Hkv,D", [
    (2, 256, 4, 4, 64), (1, 512, 2, 2, 128), (2, 128, 4, 2, 64),
    (1, 256, 8, 2, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 64)])
def test_flash_attention_sweep(B, S, H, Hkv, D, dtype, causal, window,
                               rng_key):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window)
    ke = jnp.repeat(k, H // Hkv, axis=2)
    ve = jnp.repeat(v, H // Hkv, axis=2)
    ref = flash_ref(q, ke, ve, causal=causal, window=window)
    err = jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)))
    assert float(err) < _tol(dtype), float(err)


@pytest.mark.parametrize("B,S,H,Hkv,D", [(2, 512, 4, 4, 64),
                                         (1, 256, 8, 2, 32)])
@pytest.mark.parametrize("clen,window", [(300, 0), (256, 128), (512, 0)])
def test_decode_attention_sweep(B, S, H, Hkv, D, clen, window, rng_key):
    clen = min(clen, S)
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    out = decode_attention(q, k, v, clen, window=window)
    ke = jnp.repeat(k, H // Hkv, axis=2)
    ve = jnp.repeat(v, H // Hkv, axis=2)
    ref = decode_ref(q, ke, ve, clen, window=window)
    assert float(jnp.max(jnp.abs(out - ref))) < 5e-5


@pytest.mark.parametrize("shape", [(4, 128, 256), (2, 64, 512), (16, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype, rng_key):
    ks = jax.random.split(rng_key, 2)
    x = jax.random.normal(ks[0], shape, jnp.float32).astype(dtype)
    w = jax.random.normal(ks[1], (shape[-1],), jnp.float32) * 0.1
    out = rms_norm(x, w)
    ref = rms_ref(x, w)
    err = jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)))
    assert float(err) < _tol(dtype)


@pytest.mark.parametrize("B,S,D,N", [(2, 64, 32, 8), (1, 128, 64, 16),
                                     (3, 32, 16, 4)])
def test_ssm_scan_sweep(B, S, D, N, rng_key):
    ks = jax.random.split(rng_key, 4)
    decay = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, D, N)))
    drive = jax.random.normal(ks[1], (B, S, D, N)) * 0.1
    c = jax.random.normal(ks[2], (B, S, N))
    h0 = jax.random.normal(ks[3], (B, D, N)) * 0.1
    out = ssm_scan(decay, drive, c, h0)
    ref = ssm_ref(decay, drive, c, h0)
    assert float(jnp.max(jnp.abs(out - ref))) < 5e-5


@pytest.mark.parametrize("B,S,H,D,chunk", [(1, 128, 2, 32, 32),
                                           (2, 64, 4, 16, 16),
                                           (1, 96, 1, 64, 32)])
def test_mlstm_chunkwise_sweep(B, S, H, D, chunk, rng_key):
    ks = jax.random.split(rng_key, 5)
    q = jax.random.normal(ks[0], (B, S, H, D)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, D)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, D)) * 0.5
    ir = jax.random.normal(ks[3], (B, S, H))
    fr = jax.random.normal(ks[4], (B, S, H)) + 2.0
    out = mlstm_chunkwise(q, k, v, ir, fr, chunk=chunk)
    ref = mlstm_ref(q, k, v, ir, fr)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4


def test_flash_attention_grad_matches_ref(rng_key):
    """The training path's flash attention (pure-JAX blockwise scan with the
    remat contract) is gradient-equivalent to the naive oracle.  (Pallas
    interpret mode does not autodiff through ``pl.program_id``; on real TPU
    the kernel gets a custom VJP — the model's train path uses this
    blockwise formulation, so this is the gradient contract that matters.)"""
    from repro.models.layers import flash_attention as model_flash
    B, S, H, D = 1, 128, 2, 32
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))

    g1 = jax.grad(lambda q: jnp.sum(
        model_flash(q, k, v, causal=True, block_q=32, block_k=32) ** 2))(q)
    g2 = jax.grad(lambda q: jnp.sum(flash_ref(q, k, v, causal=True) ** 2))(q)
    assert float(jnp.max(jnp.abs(g1 - g2))) < 1e-3


@pytest.mark.parametrize("B,S,D,H", [(4, 64, 64, 4), (2, 128, 32, 2)])
def test_slstm_kernel_sweep(B, S, D, H, rng_key):
    from repro.kernels.slstm import reference as slstm_ref
    from repro.kernels.slstm import slstm_recurrence
    ks = jax.random.split(rng_key, 2)
    xp = jax.random.normal(ks[0], (B, S, 4 * D)) * 0.5
    r = jax.random.normal(ks[1], (4, H, D // H, D // H)) * 0.3
    out = slstm_recurrence(xp, r, H)
    ref = slstm_ref(xp, r)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4
