"""Linker (host+device merge) and converter (verify + canonicalize)."""
import pytest

from repro.core import ExecutionTrace, NodeType, convert, link
from repro.core.converter import ConvertReport, verify_and_clean


def _host():
    et = ExecutionTrace(metadata={"side": "host"})
    a = et.add_node(name="embed", type=NodeType.COMP,
                    attrs={"scope": "embed", "op": "gather"})
    b = et.add_node(name="dot1", type=NodeType.COMP,
                    attrs={"scope": "layer/dot1", "op": "dot_general"})
    b.data_deps.append(a.id)
    c = et.add_node(name="psum", type=NodeType.COMM_COLL,
                    attrs={"scope": "layer/psum", "op": "psum"})
    c.data_deps.append(b.id)
    return et


def _device():
    et = ExecutionTrace(metadata={"side": "device"})
    d1 = et.add_node(name="dot.1", type=NodeType.COMP,
                     attrs={"scope": "layer/dot1", "op": "dot"})
    d2 = et.add_node(name="fusion.2", type=NodeType.COMP,
                     attrs={"scope": "unmatched/xyz", "op": "fusion"})
    d2.data_deps.append(d1.id)
    ar = et.add_node(name="all-reduce.3", type=NodeType.COMM_COLL,
                     attrs={"scope": "nomatch", "op": "all-reduce"})
    ar.sync_deps.append(d2.id)
    return et


def test_link_merges_and_anchors():
    merged, report = link(_host(), _device())
    assert report.host_nodes == 3 and report.device_nodes == 3
    assert report.matched == 1                      # exact scope match
    assert report.kind_matched >= 1                 # all-reduce ~ psum
    assert merged.is_acyclic()
    levels = {n.attrs.get("level") for n in merged}
    assert {"host", "device"} <= levels
    assert report.sync_edges == 1


def test_convert_removes_bad_edges_and_canonicalizes():
    et = ExecutionTrace()
    a = et.add_node(name="a")
    b = et.add_node(name="b")
    b.data_deps += [a.id, a.id, 999]        # dup + dangling
    b.ctrl_deps += [a.id, b.id]             # redundant ctrl + self
    out, report = convert(et)
    assert report.dup_deps_removed == 1
    assert report.dangling_deps_removed == 1
    assert report.self_deps_removed == 1
    assert report.redundant_ctrl_removed == 1
    assert out.is_acyclic()
    # canonical: ids are a topological order starting at 0
    assert sorted(out.nodes) == list(range(len(out)))


def test_convert_breaks_cycles():
    et = ExecutionTrace()
    a = et.add_node(name="a")
    b = et.add_node(name="b")
    a.data_deps.append(b.id)
    b.ctrl_deps.append(a.id)        # ctrl edge is the weakest: dropped first
    out, report = convert(et)
    assert report.cycle_edges_broken == 1
    assert out.is_acyclic()


def test_convert_fixes_comm_nodes():
    et = ExecutionTrace()
    et.add_node(name="c", type=NodeType.COMM_COLL, comm_group=42)
    out, report = convert(et)
    assert report.comm_nodes_fixed >= 1
    node = out.sorted_nodes()[0]
    assert node.comm_group == -1            # unknown group cleared
    assert node.comm_type != 0
