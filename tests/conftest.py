import os
import sys

# smoke tests and benches must see ONE device (the dry-run sets its own
# XLA_FLAGS before any import; never set device-count flags globally here)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

from repro.configs import base as config_base


@pytest.fixture(scope="session", autouse=True)
def _register_smoke_shapes():
    config_base.SHAPES.setdefault(
        "smoke_dec", config_base.ShapeSpec("smoke_dec", 32, 2, "decode"))
    yield


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
