"""repro.explore: spec expansion, content-addressed caching, parallel
execution, failure isolation, ranked reports, and the acceptance gates
(≥200-config parallel sweep, zero-resim cached replay, byte-identical
grid/report determinism, Fig-12 re-ranking from one spec file)."""
import json
import os

import pytest

from repro.explore import (ExperimentSpec, RunCache, RunConfig, as_spec,
                           build_report, build_workload, execute_run,
                           render_markdown, report_json_bytes, run_sweep)
from repro.explore.runner import RESULT_COLUMNS
from repro.pipeline.registry import make_stage


def mini_spec(**over):
    d = {
        "name": "mini",
        "workloads": [
            {"pattern": "moe_mixed", "args": {"mode": "allreduce",
                                              "iters": 2}},
            {"pattern": "moe_mixed", "args": {"mode": "alltoall",
                                              "iters": 2}},
        ],
        "axes": {"topology": ["ring", "switch"], "world_size": [4]},
    }
    d.update(over)
    return ExperimentSpec.from_dict(d)


# ----------------------------------------------------------------- spec
def test_spec_validation_errors():
    with pytest.raises(ValueError, match="at least one workload"):
        ExperimentSpec.from_dict({"name": "x", "workloads": []})
    with pytest.raises(ValueError, match="exactly one of"):
        ExperimentSpec.from_dict({"workloads": [{"pattern": "a",
                                                 "scenario": "b"}]})
    with pytest.raises(ValueError, match="unknown axes"):
        mini_spec(axes={"warp_speed": [9]})
    with pytest.raises(ValueError, match="no values"):
        mini_spec(axes={"topology": []})
    with pytest.raises(ValueError, match="sample mode"):
        mini_spec(sample={"mode": "psychic"})
    with pytest.raises(ValueError, match="duplicate workload name"):
        ExperimentSpec.from_dict({"workloads": [
            {"pattern": "moe_mixed"}, {"pattern": "moe_mixed"}]})
    with pytest.raises(ValueError, match="unknown spec keys"):
        ExperimentSpec.from_dict({"workloads": [{"pattern": "a"}],
                                  "axis": {}})


def test_grid_expansion_counts_and_defaults():
    spec = mini_spec()
    assert spec.grid_size() == 2 * 2 * 1
    cfgs = spec.expand()
    assert len(cfgs) == 4
    # defaults fill unswept axes so every config is fully specified
    assert all(c.fidelity == "analytic" and c.scale_comm_bytes == 1.0
               for c in cfgs)
    # expansion order: workload-major, then axis order
    assert [c.label() for c in cfgs[:2]] == [
        "moe_mixed-allreduce/ringx4@analytic",
        "moe_mixed-allreduce/switchx4@analytic"]


def test_expansion_byte_identical_for_same_spec_and_seed():
    a = mini_spec(seed=3).expansion_json()
    b = mini_spec(seed=3).expansion_json()
    assert a == b
    assert a != mini_spec(seed=4).expansion_json()


def test_run_hash_content_addressing():
    c1, c2 = mini_spec().expand()[:2]
    assert c1.run_hash != c2.run_hash            # topology differs
    assert len(c1.run_hash) == 64
    # hash is content-based: rebuilding from the dict round-trips it
    assert RunConfig.from_dict(c1.to_dict()).run_hash == c1.run_hash
    # ... and is insensitive to workload key order
    w = {"args": {"iters": 2, "mode": "allreduce"}, "pattern": "moe_mixed",
         "name": "moe_mixed-allreduce"}
    d = c1.to_dict()
    d["workload"] = w
    assert RunConfig.from_dict(d).run_hash == c1.run_hash


def test_random_sampling_deterministic_subset():
    spec = mini_spec(sample={"mode": "random", "n": 3, "seed": 11},
                     axes={"topology": ["ring", "switch", "clos"],
                           "world_size": [4, 8]})
    picks = [c.run_hash for c in spec.expand()]
    assert len(picks) == 3 and len(set(picks)) == 3
    assert picks == [c.run_hash for c in spec.expand()]
    grid = {c.run_hash
            for c in mini_spec(axes=spec.axes).expand()}
    assert set(picks) <= grid
    # n >= grid size degrades to the full grid
    big = mini_spec(sample={"mode": "random", "n": 99, "seed": 1})
    assert len(big.expand()) == big.grid_size()


def test_as_spec_coercions(tmp_path):
    spec = mini_spec()
    path = spec.save(str(tmp_path / "spec.json"))
    assert as_spec(str(path)).spec_hash() == spec.spec_hash()
    assert as_spec(spec.to_dict()).spec_hash() == spec.spec_hash()
    with pytest.raises(ValueError):
        as_spec(42)


# ------------------------------------------------------------- workloads
def test_build_workload_kinds(tmp_path):
    spec = mini_spec()
    traces = build_workload(spec.expand()[0])
    assert len(traces) == 1 and len(traces[0]) > 0    # single-trace what-if
    sc = ExperimentSpec.from_dict({
        "workloads": [{"scenario": "dp-dense"}],
        "axes": {"world_size": [2], "steps": [2]}})
    traces = build_workload(sc.expand()[0])
    assert len(traces) == 2                           # synthesized per rank
    assert all(len(t) > 0 for t in traces)
    from repro.core.serialization import save
    p = str(tmp_path / "r0.chkb")
    save(traces[0], p, version=4)
    ck = ExperimentSpec.from_dict({"workloads": [{"chkb": [p]}]})
    loaded = build_workload(ck.expand()[0])
    assert len(loaded) == 1 and len(loaded[0]) == len(traces[0])


def test_execute_run_row_shape():
    row = execute_run(mini_spec().expand()[0])
    assert row["ok"] and not row["cached"]
    assert row["makespan_s"] > 0 and row["total_nodes"] > 0
    assert row["cost"] == pytest.approx(4 * row["link_bw"])
    for col in RESULT_COLUMNS:
        assert col in row or col in ("error",), col


# ------------------------------------------------------------------ sweep
def test_sweep_cache_replay_executes_zero_simulations(tmp_path):
    spec = mini_spec()
    cache = str(tmp_path / "cache")
    cold = run_sweep(spec, jobs=1, cache_dir=cache)
    assert cold.executed == 4 and cold.cached == 0 and cold.failed == 0
    warm = run_sweep(spec, jobs=1, cache_dir=cache)
    assert warm.executed == 0 and warm.cached == 4   # zero re-simulations
    assert [r["hash"] for r in warm.rows] == [r["hash"] for r in cold.rows]
    # incremental spec edit: only the new configs execute
    grown = mini_spec(axes={"topology": ["ring", "switch", "clos"],
                            "world_size": [4]})
    inc = run_sweep(grown, jobs=1, cache_dir=cache)
    assert inc.executed == 2 and inc.cached == 4


def test_sweep_parallel_matches_serial(tmp_path):
    spec = mini_spec()
    serial = run_sweep(spec, jobs=1)
    parallel = run_sweep(spec, jobs=2)
    ks = ("hash", "makespan_s", "exposed_comm_s", "comm_time_total_s")
    assert ([{k: r[k] for k in ks} for r in serial.rows]
            == [{k: r[k] for k in ks} for r in parallel.rows])


def test_sweep_isolates_per_run_failures(tmp_path):
    # tpu_pod with a prime world size cannot form a torus: that run fails,
    # the rest of the sweep completes
    spec = mini_spec(axes={"topology": ["ring", "tpu_pod"],
                           "world_size": [7]})
    res = run_sweep(spec, jobs=1, cache_dir=str(tmp_path / "c"))
    assert res.failed == 2 and len(res.rows) == 4
    bad = [r for r in res.rows if not r["ok"]]
    assert all("tpu_pod" == r["topology"] and "ValueError" in r["error"]
               for r in bad)
    # failures are never cached: a fixed engine would re-run them
    assert run_sweep(spec, jobs=1,
                     cache_dir=str(tmp_path / "c")).executed == 2
    # ... and they surface in the report, not as rankings
    doc = build_report(res)
    assert doc["runs"]["failed"] == 2 and len(doc["failures"]) == 2


def test_cache_rejects_corrupt_and_mismatched_entries(tmp_path):
    cache = RunCache(str(tmp_path))
    cfg = mini_spec().expand()[0]
    row = execute_run(cfg)
    cache.put(row)
    assert cache.get(cfg.run_hash)["cached"] is True
    with open(cache.path(cfg.run_hash), "w") as fh:
        fh.write("{not json")
    assert cache.get(cfg.run_hash) is None
    assert cache.get("0" * 64) is None


# ----------------------------------------------------------------- report
def test_report_ranking_pareto_sensitivity():
    spec = mini_spec(axes={"topology": ["ring", "switch"],
                           "world_size": [4, 8],
                           "link_bw": [2.5e10, 5e10]})
    res = run_sweep(spec, jobs=1)
    doc = build_report(res)
    for name, w in doc["workloads"].items():
        ranking = w["ranking"]
        assert len(ranking) == 8
        makespans = [e["makespan_s"] for e in ranking]
        assert makespans == sorted(makespans)
        assert w["best"] == ranking[0]
        # pareto: non-dominated on (cost, makespan)
        pareto = w["pareto"]
        assert pareto
        for p in pareto:
            assert not any(e["cost"] < p["cost"]
                           and e["makespan_s"] < p["makespan_s"]
                           for e in ranking)
        # swept axes appear in the sensitivity table, collapsed ones don't
        assert "topology" in w["sensitivity"]
        assert "world_size" in w["sensitivity"]
        assert "fidelity" not in w["sensitivity"]
        assert w["sensitivity"]["topology"]["delta_pct"] is not None
    md = render_markdown(doc)
    assert "Pareto frontier" in md and "| topology |" in md


def test_report_byte_identical_fresh_vs_cached(tmp_path):
    spec = mini_spec(seed=5)
    cache = str(tmp_path / "cache")
    fresh = report_json_bytes(build_report(run_sweep(spec, jobs=1,
                                                     cache_dir=cache)))
    cached = report_json_bytes(build_report(run_sweep(spec, jobs=1,
                                                      cache_dir=cache)))
    nocache = report_json_bytes(build_report(run_sweep(spec, jobs=2)))
    assert fresh == cached == nocache


# -------------------------------------------------------------- registry
def test_registry_stages_dispatch():
    res = make_stage("experiment", "explore.run", mini_spec(), jobs=1)
    doc = make_stage("experiment", "explore.report", res)
    assert doc["runs"]["total"] == 4 and doc["schema"].startswith(
        "repro-explore-report")


# ------------------------------------------------- acceptance: Fig-12 spec
FIG12_SPEC = {
    "name": "fig12",
    "workloads": [
        {"pattern": "moe_mixed", "args": {"mode": "allreduce", "iters": 4}},
        {"pattern": "moe_mixed", "args": {"mode": "alltoall", "iters": 4}},
    ],
    "axes": {
        "topology": ["ring", "switch", "clos", "fully_connected"],
        "world_size": [8],
        "fidelity": ["link"],
    },
}


def test_fig12_reranking_from_one_spec():
    """The paper's co-design headline as a single declarative spec: ring
    wins the allreduce-heavy workload, the point-to-point fabrics win the
    a2a-heavy one — emergent from the routed link model."""
    doc = build_report(run_sweep(ExperimentSpec.from_dict(FIG12_SPEC),
                                 jobs=2))
    best = {name: w["best"]["topology"]
            for name, w in doc["workloads"].items()}
    assert best["moe_mixed-allreduce"] == "ring"
    assert best["moe_mixed-alltoall"] in ("switch", "clos",
                                          "fully_connected")


def test_big_sweep_process_parallel_via_cli(tmp_path, capsys):
    """≥200-config sweep, process-parallel, through `python -m repro
    explore`; the repeated run completes from cache alone (zero
    simulations) and the report JSON is byte-identical."""
    from repro import cli
    spec_dict = {
        "name": "big",
        "workloads": [
            {"pattern": "moe_mixed", "args": {"mode": "allreduce",
                                              "iters": 2}},
            {"pattern": "moe_mixed", "args": {"mode": "alltoall",
                                              "iters": 2}},
        ],
        "axes": {
            "topology": ["ring", "switch", "clos", "fully_connected",
                         "tpu_pod"],
            "world_size": [4, 8, 16],
            "link_bw": [2.5e10, 5e10],
            "latency_s": [1e-6, 2e-6],
            "fidelity": ["analytic", "link"],
        },
    }
    assert ExperimentSpec.from_dict(spec_dict).grid_size() == 240
    sp = str(tmp_path / "big.json")
    json.dump(spec_dict, open(sp, "w"))
    cache = str(tmp_path / "cache")
    rj1, rj2 = str(tmp_path / "r1.json"), str(tmp_path / "r2.json")
    assert cli.main(["explore", sp, "--jobs", "4", "--cache-dir", cache,
                     "--json", rj1]) == 0
    assert "240 configs, 240 simulated, 0 cached, 0 failed" \
        in capsys.readouterr().out
    assert cli.main(["explore", sp, "--jobs", "4", "--cache-dir", cache,
                     "--json", rj2]) == 0
    assert "240 configs, 0 simulated, 240 cached" in capsys.readouterr().out
    assert open(rj1, "rb").read() == open(rj2, "rb").read()


# -------------------------------------------------- review regression fixes
def test_explicit_zero_jitter_overrides_scenario_default():
    """An explicit jitter axis value — including 0.0 — must beat the
    scenario's default, so sweeping jitter actually sweeps it."""
    spec = ExperimentSpec.from_dict({
        "workloads": [{"scenario": "straggler-jitter"}],
        "axes": {"world_size": [2], "steps": [2],
                 "jitter": [0.0, 0.6], "stragglers": [{}]}})
    zero, jittered = spec.expand()
    assert zero.jitter == 0.0 and jittered.jitter == 0.6
    dur = lambda t: sum(n.duration_micros for n in t)
    t_zero = build_workload(zero)
    t_jit = build_workload(jittered)
    # jitter=0.0 must NOT fall back to the scenario default (0.3): the
    # jittered grid point perturbs durations, the zero point doesn't
    assert dur(t_zero[0]) != dur(t_jit[0])
    # explicit {} also disables the scenario's default straggler: both
    # synthesized ranks run at the same speed under jitter 0.0
    assert dur(t_zero[0]) == pytest.approx(dur(t_zero[1]))
    # unswept (None) keeps the scenario's character: rank 0 is 1.8x slow
    default = ExperimentSpec.from_dict({
        "workloads": [{"scenario": "straggler-jitter"}],
        "axes": {"world_size": [2], "steps": [2]}})
    t0, t1 = build_workload(default.expand()[0])
    comp = lambda t: sum(n.duration_micros for n in t if not n.is_comm)
    assert comp(t0) > 1.5 * comp(t1)


def test_cli_seed_redraws_random_sample(tmp_path, capsys):
    from repro import cli
    spec = {"workloads": [{"pattern": "moe_mixed", "args": {"iters": 2}}],
            "axes": {"topology": ["ring", "switch", "clos",
                                  "fully_connected"],
                     "world_size": [2, 4, 8]}}
    sp = str(tmp_path / "s.json")
    json.dump(spec, open(sp, "w"))

    def grid(seed):
        assert cli.main(["explore", sp, "--dry-run", "--sample", "4",
                         "--seed", seed]) == 0
        doc = json.loads(capsys.readouterr().out)
        return [(c["topology"], c["world_size"]) for c in doc["configs"]]

    g1, g2 = grid("1"), grid("2")
    assert len(g1) == len(g2) == 4
    assert g1 != g2                     # --seed redraws the sample
    assert g1 == grid("1")              # ... deterministically


def test_chkb_workload_cache_invalidates_on_file_change(tmp_path):
    from repro.core import generator
    from repro.core.serialization import save
    p = str(tmp_path / "w.chkb")
    save(generator.moe_mixed_collectives(iters=2, ranks=2, rank=0), p,
         version=4)
    d = {"workloads": [{"chkb": [p]}], "axes": {"topology": ["ring"]}}
    h1 = ExperimentSpec.from_dict(d).expand()[0].run_hash
    assert h1 == ExperimentSpec.from_dict(d).expand()[0].run_hash
    save(generator.moe_mixed_collectives(iters=4, ranks=2, rank=0), p,
         version=4)
    # same path, new contents: the content digest changes the run hash,
    # so a cached row for the old file can never be served
    assert ExperimentSpec.from_dict(d).expand()[0].run_hash != h1
    with pytest.raises(ValueError, match="unreadable"):
        ExperimentSpec.from_dict({"workloads": [
            {"chkb": [str(tmp_path / "missing.chkb")]}]})


def test_chkb_workload_sizes_fabric_from_file_list(tmp_path):
    from repro.core import generator
    from repro.core.serialization import save
    paths = []
    for r in range(2):
        p = str(tmp_path / f"rank{r}.chkb")
        save(generator.moe_mixed_collectives(iters=2, ranks=2, rank=r), p,
             version=4)
        paths.append(p)
    # no world_size axis: the default (8) must NOT leak into the fabric or
    # the cost proxy — the file list says this is a 2-rank job
    spec = ExperimentSpec.from_dict({"workloads": [{"chkb": paths}],
                                     "axes": {"topology": ["ring"]}})
    row = execute_run(spec.expand()[0])
    assert row["ok"] and row["ranks_simulated"] == 2
    assert row["world_size"] == 2
    assert row["cost"] == pytest.approx(2 * row["link_bw"])


def test_cli_partial_failure_exits_nonzero(tmp_path, capsys):
    from repro import cli
    spec = {"workloads": [{"pattern": "moe_mixed", "args": {"iters": 2}}],
            "axes": {"topology": ["ring", "tpu_pod"], "world_size": [7]}}
    sp = str(tmp_path / "s.json")
    json.dump(spec, open(sp, "w"))
    assert cli.main(["explore", sp, "--jobs", "1",
                     "--cache-dir", str(tmp_path / "c")]) == 1
    assert "1/2 run(s) failed" in capsys.readouterr().err


def test_run_sweep_validates_directly_constructed_spec():
    # the README's Python API: a hand-built spec (never from_dict'd) must
    # be normalized by run_sweep, not crash on the missing workload name
    spec = ExperimentSpec(name="direct",
                          workloads=[{"pattern": "dp_allreduce",
                                      "args": {"steps": 1, "layers": 2}}],
                          axes={"topology": ["ring"]})
    res = run_sweep(spec, jobs=1)
    assert res.failed == 0 and res.rows[0]["workload"] == "dp_allreduce"


def test_scalar_axis_value_rejected_not_charsplit():
    with pytest.raises(ValueError, match="must be a list"):
        mini_spec(axes={"topology": "ring"})


def test_cli_seed_redraws_sample_pinned_in_spec(tmp_path, capsys):
    from repro import cli
    spec = {"workloads": [{"pattern": "moe_mixed", "args": {"iters": 2}}],
            "axes": {"topology": ["ring", "switch", "clos",
                                  "fully_connected"],
                     "world_size": [2, 4, 8]},
            "sample": {"mode": "random", "n": 4, "seed": 7}}
    sp = str(tmp_path / "s.json")
    json.dump(spec, open(sp, "w"))

    def grid(extra):
        assert cli.main(["explore", sp, "--dry-run"] + extra) == 0
        doc = json.loads(capsys.readouterr().out)
        return [(c["topology"], c["world_size"]) for c in doc["configs"]]

    assert grid(["--seed", "99"]) != grid([])


def test_busiest_link_frac_is_max_over_top_links():
    spec = ExperimentSpec.from_dict({
        "workloads": [{"pattern": "moe_mixed", "args": {"iters": 3}}],
        "axes": {"topology": ["clos"], "world_size": [8],
                 "fidelity": ["link"]}})
    row = execute_run(spec.expand()[0])
    assert row["top_links"]
    assert row["busiest_link_frac"] == max(l["busy_frac"]
                                           for l in row["top_links"])


# -------------------------------------------------------------- results
def test_columnar_results_store(tmp_path):
    res = run_sweep(mini_spec(), jobs=1)
    path = res.save_results(str(tmp_path / "results.json"))
    doc = json.load(open(path))
    assert doc["schema"] == "repro-explore-results/v1"
    assert doc["count"] == 4
    cols = doc["columns"]
    assert set(cols) == set(RESULT_COLUMNS)
    assert all(len(v) == 4 for v in cols.values())
    assert all(m > 0 for m in cols["makespan_s"])
