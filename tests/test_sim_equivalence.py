"""Optimized engine vs frozen pre-optimization reference: result equivalence.

The O(log F) flow index and wake-event elimination are pure performance
changes — on every seeded scenario the optimized engine must reproduce the
reference engine's makespan, per-rank finish times, per-collective totals
and flow records within 1e-9 (they are in fact bit-identical: the flow
index prunes exactly the flows the linear scan skipped, and eliminated
wake events were no-ops by construction).
"""
import random

import pytest

from repro.core import ExecutionTrace, NodeType, CollectiveType, generator
from repro.sim import Fabric, ReferenceSimulator, SimConfig, Simulator

TOL = 1e-9


def random_comm_trace(seed: int, ranks: int) -> ExecutionTrace:
    """Random DAG mixing compute and collectives (uniform + jittered times,
    so equal-timestamp completion races are exercised)."""
    rng = random.Random(seed)
    et = ExecutionTrace(rank=0, world_size=ranks)
    pg = et.add_process_group(range(ranks), tag="g")
    n = rng.randint(20, 120)
    for i in range(n):
        if rng.random() < 0.35:
            node = et.add_node(
                name=f"c{i}", type=NodeType.COMM_COLL,
                comm_type=rng.choice((CollectiveType.ALL_REDUCE,
                                      CollectiveType.ALL_TO_ALL,
                                      CollectiveType.ALL_GATHER)),
                comm_group=pg.id, comm_bytes=rng.randint(1, 1 << 22))
        else:
            # round durations on purpose: equal completion timestamps are
            # the interesting ordering corner
            node = et.add_node(name=f"k{i}", type=NodeType.COMP,
                               duration_micros=rng.choice((0.0, 50.0, 100.0,
                                                           100.0, 237.5)))
        for dep in rng.sample(range(i), k=min(i, rng.randint(0, 2))):
            node.data_deps.append(dep)
    return et


def assert_equivalent(traces, fabric, cfg=None):
    ref = ReferenceSimulator(traces, fabric, cfg).run()
    new = Simulator(traces, fabric, cfg).run()
    assert abs(ref.makespan_s - new.makespan_s) <= TOL
    assert len(ref.per_rank_finish_s) == len(new.per_rank_finish_s)
    for a, b in zip(ref.per_rank_finish_s, new.per_rank_finish_s):
        assert abs(a - b) <= TOL
    assert set(ref.collective_time_s) == set(new.collective_time_s)
    for k, v in ref.collective_time_s.items():
        assert abs(v - new.collective_time_s[k]) <= TOL, k
    assert ref.collective_bytes == new.collective_bytes
    assert len(ref.flows) == len(new.flows)
    for fa, fb in zip(ref.flows, new.flows):
        assert fa.kind == fb.kind
        assert abs(fa.start_s - fb.start_s) <= TOL
        assert abs(fa.end_s - fb.end_s) <= TOL
        assert abs(fa.throttled - fb.throttled) <= TOL
    assert abs(ref.compute_busy_s - new.compute_busy_s) <= TOL
    assert abs(ref.exposed_comm_s - new.exposed_comm_s) <= TOL
    return ref, new


@pytest.mark.parametrize("mode", ["mixed", "alltoall", "allreduce"])
def test_moe_multirank_equivalence(mode):
    traces = [generator.moe_mixed_collectives(iters=6, ranks=8, mode=mode,
                                              rank=r) for r in range(8)]
    assert_equivalent(traces, Fabric.build("switch", 8))


@pytest.mark.parametrize("topo", ["switch", "ring", "fully_connected"])
def test_dp_allreduce_equivalence_topologies(topo):
    # uniform compute durations: every rank completes at identical
    # timestamps, the densest same-time event collision pattern
    traces = [generator.dp_allreduce_pattern(steps=3, layers=6, ranks=4,
                                             rank=r) for r in range(4)]
    assert_equivalent(traces, Fabric.build(topo, 4))


def test_straggler_and_no_congestion_equivalence():
    traces = [generator.dp_allreduce_pattern(steps=2, layers=4, ranks=4,
                                             rank=r) for r in range(4)]
    fab = Fabric.build("switch", 4)
    assert_equivalent(traces, fab, SimConfig(speed_factors={1: 0.4, 3: 2.0}))
    assert_equivalent(traces, fab, SimConfig(congestion=False))


def test_single_trace_equivalence():
    et = generator.moe_mixed_collectives(iters=10, ranks=8)
    assert_equivalent([et], Fabric.build("switch", 8))
    assert_equivalent([generator.compute_chain(n=64)], Fabric.build("ring", 2))


@pytest.mark.parametrize("seed", range(12))
def test_random_comm_traces_equivalence(seed):
    ranks = random.Random(seed).choice((2, 4, 8))
    traces = [random_comm_trace(seed * 31 + r, ranks) for r in range(ranks)]
    # per-rank traces differ structurally -> rendezvous occurrences only
    # match per (type, group, tag) stream; that is exactly what the engine
    # keys on, and both engines must agree on the resulting schedule
    assert_equivalent(traces, Fabric.build("switch", ranks))


def test_same_instant_completions_keep_concurrent_issues():
    """Two collectives completing at the same instant must grant the rank
    two same-instant issue opportunities, exactly like the reference —
    naive wake dedup would serialize the dependent computes (2x makespan)."""
    et = ExecutionTrace(rank=0, world_size=2)
    pg = et.add_process_group([0], tag="solo")
    ar1 = et.add_node(name="ar1", type=NodeType.COMM_COLL,
                      comm_type=CollectiveType.ALL_REDUCE,
                      comm_group=pg.id, comm_bytes=1 << 20)
    ar2 = et.add_node(name="ar2", type=NodeType.COMM_COLL,
                      comm_type=CollectiveType.ALL_REDUCE,
                      comm_group=pg.id, comm_bytes=1 << 20)
    for ar in (ar1, ar2):
        c = et.add_node(name=f"c_{ar.name}", type=NodeType.COMP,
                        duration_micros=100.0)
        c.data_deps.append(ar.id)
    # congestion off => identical flow durations => same-instant completions
    ref, new = assert_equivalent([et], Fabric.build("switch", 2),
                                 SimConfig(congestion=False))
    assert new.flows[0].end_s == new.flows[1].end_s  # the tie really happened
    assert_equivalent([et], Fabric.build("switch", 2))


def test_deferred_readiness_same_instant():
    """First same-instant completion readies nothing, the second readies
    two nodes at once: the banked wake credit must flush so both issue at
    that instant, as the reference's two wakes would."""
    et = ExecutionTrace(rank=0, world_size=2)
    pg = et.add_process_group([0], tag="solo")
    ar1 = et.add_node(name="ar1", type=NodeType.COMM_COLL,
                      comm_type=CollectiveType.ALL_REDUCE,
                      comm_group=pg.id, comm_bytes=1 << 20)
    ar2 = et.add_node(name="ar2", type=NodeType.COMM_COLL,
                      comm_type=CollectiveType.ALL_REDUCE,
                      comm_group=pg.id, comm_bytes=1 << 20)
    for i in range(2):
        c = et.add_node(name=f"c{i}", type=NodeType.COMP,
                        duration_micros=100.0)
        c.data_deps.extend([ar1.id, ar2.id])   # ready only after BOTH
    assert_equivalent([et], Fabric.build("switch", 2),
                      SimConfig(congestion=False))
    assert_equivalent([et], Fabric.build("switch", 2))


def test_new_engine_processes_fewer_events():
    """The wake-elimination must actually eliminate events (and never add)."""
    traces = [generator.moe_mixed_collectives(iters=20, ranks=8, rank=r)
              for r in range(8)]
    fab = Fabric.build("switch", 8)
    ref = ReferenceSimulator(traces, fab).run()
    new = Simulator(traces, fab).run()
    assert new.events < ref.events


def test_flow_index_memory_bounded():
    """active-flow state must not grow with trace length (satellite fix:
    the reference keeps every flow ever launched, even with congestion off)."""
    from repro.sim.engine import _FlowIndex
    idx = _FlowIndex()
    t = 0.0
    for i in range(10_000):
        idx.add(t + 1.0, 2, i % 5 == 0)
        t += 0.5
        idx.flows_at(t)
        assert len(idx) <= 4
    assert idx.flows_at(t + 10.0) == 0
    assert not idx.fat_at(t + 10.0)
