"""CHKB v4 columnar blocks: round-trip, v3 interop, byte-compat guarantees.

The back-compat anchors:
* ``tests/data/golden_v3.chkb`` was written by the v3 (row-block) encoder
  with ``compress=False`` — it must keep loading, and re-encoding it with
  ``version=3`` must reproduce the file byte-for-byte (the streaming-byte-
  identity guarantee from the pipeline PR is pinned to v3 forever).
* ``ChkbWriter(version=...)`` streaming output must equal the one-shot
  ``to_chkb_bytes`` for BOTH versions.
"""
import dataclasses
import hashlib
import os

import pytest

from repro.core import (CollectiveType, ETNode, ExecutionTrace, NodeType,
                        from_chkb_bytes, to_chkb_bytes)
from repro.core.serialization import (ChkbReader, ChkbWriter, NodeColumns,
                                      iter_chkb_nodes, load, roundtrip_equal,
                                      save)

GOLDEN = os.path.join(os.path.dirname(__file__), "data", "golden_v3.chkb")
GOLDEN_SHA = "6e058b397ce74efc5a49fc1322f06670cb94cc7f4fe95e8ac4bfd76d2eaa5915"

FIELDS = [f.name for f in dataclasses.fields(ETNode)]


def rich_trace() -> ExecutionTrace:
    et = ExecutionTrace(rank=1, world_size=4, metadata={"m": 1})
    pg = et.add_process_group([0, 1, 2, 3], tag="dp")
    for i, ntype in enumerate(list(NodeType) * 3):
        n = et.add_node(
            name=f"node/{ntype.name}/{i}", type=ntype,
            start_time_micros=1.5 * i, duration_micros=0.25 * i,
            comm_type=CollectiveType((i % 8) + 1) if i % 2 else
            CollectiveType.INVALID,
            comm_group=pg.id if i % 3 else -1,
            comm_bytes=1 << (i % 24), comm_src=i % 5 - 1, comm_dst=i % 7 - 1,
            comm_tag=f"tag{i}" if i % 4 == 0 else "",
            inputs=[i, i + 1] if i % 3 == 0 else [],
            outputs=[i * 2] if i % 5 == 0 else [],
            attrs={"op": "dot", "k": [i, {"x": 1}]} if i % 6 == 0 else {},
        )
        if i:
            n.ctrl_deps.append(i - 1)
        if i > 2:
            n.data_deps.extend([i - 2, i - 3])
        if i > 4:
            n.sync_deps.append(i - 5)
    return et


def assert_nodes_equal(a: ExecutionTrace, b: ExecutionTrace) -> None:
    assert sorted(a.nodes) == sorted(b.nodes)
    for nid in a.nodes:
        for f in FIELDS:
            assert getattr(a.nodes[nid], f) == getattr(b.nodes[nid], f), (
                f"field {f} of node {nid} changed")


@pytest.mark.parametrize("version", [3, 4])
@pytest.mark.parametrize("compress", [True, False])
def test_roundtrip_both_versions(version, compress):
    et = rich_trace()
    back = from_chkb_bytes(to_chkb_bytes(et, block_size=5, version=version,
                                         compress=compress))
    assert_nodes_equal(et, back)


def test_v3_v4_cross_version_equal():
    et = rich_trace()
    a = from_chkb_bytes(to_chkb_bytes(et, version=3, block_size=7))
    b = from_chkb_bytes(to_chkb_bytes(et, version=4, block_size=7))
    assert roundtrip_equal(a, b)
    assert_nodes_equal(a, b)


@pytest.mark.parametrize("version", [3, 4])
def test_streaming_writer_matches_oneshot(version):
    et = rich_trace()
    w = ChkbWriter(et.skeleton(), block_size=4, version=version)
    # stream in several ragged batches
    nodes = et.sorted_nodes()
    w.add_nodes(nodes[:3])
    w.add_nodes(nodes[3:10])
    w.add_nodes(nodes[10:])
    assert w.getvalue() == to_chkb_bytes(et, block_size=4, version=version)


def test_golden_v3_file_loads_and_reencodes_byte_identically():
    with open(GOLDEN, "rb") as fh:
        data = fh.read()
    # the committed fixture itself is what the pre-v4 writer produced
    assert hashlib.sha256(data).hexdigest() == GOLDEN_SHA
    et = from_chkb_bytes(data)
    assert len(et) == 161
    assert et.rank == 2 and et.world_size == 8
    # v3 re-encode reproduces the pre-v4 writer's bytes exactly
    assert to_chkb_bytes(et, block_size=32, compress=False, version=3) == data


def test_golden_v3_reader_and_feeder(tmp_path):
    from repro.core.feeder import ETFeeder
    r = ChkbReader(GOLDEN)
    assert r.version == 3
    assert r.node_count == 161
    order = ETFeeder(GOLDEN, window=16).drain_order()
    assert len(order) == 161


def test_version_switch_default_and_magic(tmp_path):
    et = rich_trace()
    p3 = str(tmp_path / "a3.chkb")
    p4 = str(tmp_path / "a4.chkb")
    save(et, p3, version=3)
    save(et, p4)                      # default is the columnar encoding
    with open(p3, "rb") as fh:
        assert fh.read(8) == b"CHKB\x00\x03\x00\x00"
    with open(p4, "rb") as fh:
        assert fh.read(8) == b"CHKB\x00\x04\x00\x00"
    assert ChkbReader(p3).version == 3
    assert ChkbReader(p4).version == 4
    assert roundtrip_equal(load(p3), load(p4))


def test_unknown_version_rejected():
    et = rich_trace()
    with pytest.raises(ValueError):
        to_chkb_bytes(et, version=9)
    data = bytearray(to_chkb_bytes(et, version=4))
    data[5] = 9
    with pytest.raises(ValueError):
        from_chkb_bytes(bytes(data))


def test_v4_tolerates_whole_float_int_fields():
    # JSON/v3 tooling emits e.g. comm_bytes: 100.0; v4 must accept it
    et = ExecutionTrace()
    et.add_node(ETNode(id=0, name="m", type=NodeType.MEM_LOAD,
                       comm_bytes=100.0, comm_src=1.0, comm_dst=2.0))
    back = from_chkb_bytes(to_chkb_bytes(et, version=4))
    assert back.nodes[0].comm_bytes == 100
    # a genuinely fractional value is a schema violation named by field
    et2 = ExecutionTrace()
    et2.add_node(ETNode(id=0, name="m", comm_bytes=100.5))
    with pytest.raises(ValueError, match="comm_bytes"):
        to_chkb_bytes(et2, version=4)


def test_iter_chkb_nodes_both_versions():
    et = rich_trace()
    for version in (3, 4):
        data = to_chkb_bytes(et, block_size=6, version=version)
        ids = [n.id for n in iter_chkb_nodes(data)]
        assert ids == sorted(et.nodes)


def test_node_columns_access(tmp_path):
    et = rich_trace()
    p = str(tmp_path / "c.chkb")
    save(et, p, version=4, block_size=8)
    with ChkbReader(p) as r:
        cols = r.read_block_columns(0)
        assert isinstance(cols, NodeColumns)
        assert len(cols) == 8
        nodes = et.sorted_nodes()[:8]
        assert cols.ids == [n.id for n in nodes]
        assert cols.comm_bytes == [n.comm_bytes for n in nodes]
        assert cols.durations == [float(n.duration_micros) for n in nodes]
        assert cols.names == [n.name for n in nodes]
        # lazy materialization round-trips every field
        for got, want in zip(cols.to_nodes(), nodes):
            for f in FIELDS:
                assert getattr(got, f) == getattr(want, f)
        assert sum(c.count for c in r.iter_column_blocks()) == r.node_count

    # columnar access on a v3 file is a clear error
    p3 = str(tmp_path / "c3.chkb")
    save(et, p3, version=3)
    with ChkbReader(p3) as r3:
        with pytest.raises(ValueError):
            r3.read_block_columns(0)


def test_columnar_summary_matches_materialized(tmp_path):
    from collections import Counter

    from repro.core import generator
    from repro.core.analysis import columnar_summary

    et = generator.moe_mixed_collectives(iters=40, ranks=8)
    p = str(tmp_path / "m.chkb")
    save(et, p, version=4, block_size=64)
    got = columnar_summary(p)
    assert got["nodes"] == len(et)
    assert got["total_bytes"] == et.total_bytes()
    assert got["edges"] == sum(
        len(n.ctrl_deps) + len(n.data_deps) + len(n.sync_deps) for n in et)
    want_types = Counter(int(n.type) for n in et)
    assert got["node_type_counts"] == {
        NodeType(t).name: c for t, c in sorted(want_types.items())}
    ar = got["comm_summary"]["AllReduce"]
    assert ar["count"] == sum(
        1 for n in et.comm_nodes()
        if n.comm_type == CollectiveType.ALL_REDUCE)
