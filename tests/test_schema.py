"""Schema + serialization: structure, topological properties, roundtrips
(including seeded-random property tests over randomized DAGs)."""
import random

import pytest

from repro.core import (CollectiveType, ETNode, ExecutionTrace, NodeType,
                        from_chkb_bytes, from_json_bytes, to_chkb_bytes,
                        to_json_bytes)
from repro.core.serialization import ChkbReader, roundtrip_equal, save, load


# ------------------------------------------------------- generators
def random_dag_trace(seed: int) -> ExecutionTrace:
    rng = random.Random(seed)
    n = rng.randint(1, 60)
    et = ExecutionTrace(rank=rng.randint(0, 3), world_size=4)
    pg = et.add_process_group(tuple(range(4)), tag="model")
    for i in range(n):
        ntype = rng.choice([NodeType.COMP, NodeType.COMM_COLL,
                            NodeType.MEM_LOAD])
        node = et.add_node(name=f"n{i}", type=ntype,
                           duration_micros=rng.uniform(0, 1e3))
        if ntype == NodeType.COMM_COLL:
            node.comm_type = rng.choice(
                [CollectiveType.ALL_REDUCE, CollectiveType.ALL_TO_ALL])
            node.comm_group = pg.id
            node.comm_bytes = rng.randint(0, 1 << 20)
        elif ntype == NodeType.MEM_LOAD:
            node.comm_bytes = rng.randint(0, 1 << 20)
        # edges only to earlier nodes => acyclic by construction
        if i:
            for dep in rng.sample(range(i), k=min(i, rng.randint(0, 3))):
                kind = rng.choice(["data_deps", "ctrl_deps", "sync_deps"])
                getattr(node, kind).append(dep)
    return et


@pytest.mark.parametrize("seed", range(30))
def test_random_dag_is_acyclic_and_orders(seed):
    et = random_dag_trace(seed)
    order = et.topological_order()
    assert sorted(order) == sorted(et.nodes)
    pos = {nid: i for i, nid in enumerate(order)}
    for n in et.nodes.values():
        for d, _ in n.all_deps():
            assert pos[d] < pos[n.id]


@pytest.mark.parametrize("seed", range(30))
def test_json_roundtrip(seed):
    et = random_dag_trace(seed)
    assert roundtrip_equal(et, from_json_bytes(to_json_bytes(et)))


@pytest.mark.parametrize("seed", range(30))
def test_chkb_roundtrip(seed):
    et = random_dag_trace(seed)
    block = random.Random(seed ^ 0xC0FFEE).randint(1, 16)
    data = to_chkb_bytes(et, block_size=block)
    assert roundtrip_equal(et, from_chkb_bytes(data))


def test_chkb_windowed_reader(tmp_path):
    et = ExecutionTrace()
    for i in range(100):
        n = et.add_node(name=f"n{i}", type=NodeType.COMP)
        if i:
            n.data_deps.append(i - 1)
    p = str(tmp_path / "t.chkb")
    save(et, p, block_size=8)
    with ChkbReader(p) as r:
        assert r.node_count == 100
        assert r.num_blocks == 13
        blk = r.read_block(3)
        assert [n.id for n in blk] == list(range(24, 32))
        assert len(list(r.iter_nodes())) == 100


def test_cycle_detection():
    et = ExecutionTrace()
    a = et.add_node(name="a")
    b = et.add_node(name="b")
    a.data_deps.append(b.id)
    b.data_deps.append(a.id)
    assert not et.is_acyclic()
    with pytest.raises(ValueError):
        et.topological_order()


def test_tensor_storage_alias():
    et = ExecutionTrace()
    t1 = et.add_tensor((4, 4), "f32")
    t2 = et.add_tensor((16,), "f32", storage_id=t1.storage_id,
                       storage_offset=0)
    assert t1.storage_id == t2.storage_id       # alias: same storage
    assert t1.size_bytes == t2.size_bytes == 64


def test_save_load_formats(tmp_path):
    et = ExecutionTrace(metadata={"x": 1})
    et.add_node(name="a", type=NodeType.COMP)
    for suffix in ("t.json", "t.json.zst", "t.chkb"):
        p = str(tmp_path / suffix)
        save(et, p)
        assert roundtrip_equal(et, load(p))
