"""Collection pipeline: jaxpr observer, HLO parsing/cost, capture e2e."""
import jax
import jax.numpy as jnp
import pytest

from repro.collect.capture import capture, capture_per_rank
from repro.collect.hlo_text import (collective_bytes, parse_instructions,
                                    shape_bytes)
from repro.collect.hlo_trace import build_device_trace, module_cost
from repro.collect.jaxpr_observer import observe
from repro.configs import base as config_base
from repro.core import NodeType
from repro.models import model_zoo

HLO_SAMPLE = """
HloModule test

ENTRY %main (p0: bf16[128,256]) -> bf16[128,256] {
  %p0 = bf16[128,256]{1,0} parameter(0)
  %ar = bf16[128,256]{1,0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[512,256]{1,0} all-gather(%ar), replica_groups={{0,1,2,3}}, dimensions={0}
  ROOT %out = bf16[128,256]{1,0} slice(%ag), slice={[0:128], [0:256]}
}
"""


def test_shape_bytes():
    assert shape_bytes("bf16[128,256]{1,0}") == 128 * 256 * 2
    assert shape_bytes("f32[8]") == 32
    assert shape_bytes("(f32[2,2], bf16[4])") == 16 + 8
    assert shape_bytes("token[]") == 0


def test_parse_and_collective_bytes():
    instrs = parse_instructions(HLO_SAMPLE)
    ops = {i.opcode for i in instrs}
    assert {"parameter", "all-reduce", "all-gather", "slice"} <= ops
    cb = collective_bytes(HLO_SAMPLE)
    assert cb["all-reduce"] == 128 * 256 * 2
    assert cb["all-gather"] == 128 * 256 * 2        # operand, not result
    assert cb["total"] == cb["all-reduce"] + cb["all-gather"]


def test_module_cost_scales_while_trips():
    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    hlo = jax.jit(f).lower(jnp.ones((128, 128))).compile().as_text()
    cost = module_cost(hlo)
    expected = 2 * 128 ** 3 * 10
    assert 0.9 * expected < cost["flops"] < 1.3 * expected


def test_observer_exact_ssa_deps():
    def f(a, b):
        c = a @ b
        d = jnp.tanh(c)
        return d + a

    et = observe(f, jnp.ones((4, 4)), jnp.ones((4, 4)))
    assert et.is_acyclic()
    ops = [n.attrs.get("op") for n in et.sorted_nodes()]
    assert "dot_general" in ops and "tanh" in ops
    tanh_node = next(n for n in et if n.attrs.get("op") == "tanh")
    dot_node = next(n for n in et if n.attrs.get("op") == "dot_general")
    assert dot_node.id in tanh_node.data_deps


def test_observer_compact_loops():
    def f(x):
        def body(c, _):
            return c * 2.0, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    et = observe(f, jnp.ones((4,)))
    scan_nodes = [n for n in et if n.attrs.get("op") == "scan"]
    assert len(scan_nodes) == 1
    assert scan_nodes[0].attrs["iterations"] == 7


def test_capture_pre_and_post(rng_key):
    cfg = config_base.get("deepseek-7b").reduced()
    model = model_zoo.build(cfg, model_axis=1)
    params = model.init(rng_key)
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}

    fn = lambda p, b: model.loss_fn(p, b)[0]
    pre, rep = capture(fn, params, batch, stage="pre")
    assert pre.metadata["stage"] == "pre"
    assert len(pre) > 10 and pre.is_acyclic()

    post, rep2 = capture(fn, params, batch, stage="post", execute=True)
    assert post.is_acyclic()
    assert post.metadata.get("linked")
    assert "cost" in rep2 and rep2["cost"]["flops"] > 0


def test_capture_per_rank():
    def f(x):
        return x * 2

    traces, _ = capture_per_rank(f, jnp.ones((4,)), world_size=4,
                                 stage="pre")
    assert len(traces) == 4
    assert [t.rank for t in traces] == [0, 1, 2, 3]


def test_device_trace_from_hlo():
    def f(a, b):
        return jnp.tanh(a @ b)

    hlo = jax.jit(f).lower(jnp.ones((64, 64)),
                           jnp.ones((64, 64))).compile().as_text()
    et = build_device_trace(hlo)
    assert et.is_acyclic()
    assert len(et) > 0
    assert all(n.duration_micros >= 0 for n in et)
