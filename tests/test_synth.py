"""repro.synth: profile fidelity, determinism, rank coherence, streaming.

The closed-loop acceptance test lives here: real ET -> WorkloadProfile ->
synthesize 8 coherent ranks streamed through CHKB v4 -> simulate -> summary
statistics within 10% of the source profile.
"""
import json
import os
import tracemalloc

import pytest

from repro.core import analysis
from repro.core.generator import (dp_allreduce_pattern, generate_ranks,
                                  moe_mixed_collectives)
from repro.core.schema import CollectiveType, ExecutionTrace, NodeType
from repro.core.serialization import ChkbReader, load, save
from repro.pipeline import Pipeline, available_stages
from repro.sim import Fabric, Simulator
from repro.synth import (SCENARIOS, Dist, ProfileBuilder, SplitMix64,
                         WorkloadProfile, derive_seed, get_scenario,
                         iter_rank_nodes, profile_chkb, profile_traces,
                         synthesize, synthesize_rank)
from repro.synth.profile import COMM_CATEGORIES


def _dp_traces(ranks=8):
    return generate_ranks("dp_allreduce", ranks=ranks, steps=4, layers=8)


def _moe_traces(ranks=8, iters=24):
    return generate_ranks("moe_mixed", ranks=ranks, iters=iters)


# ------------------------------------------------------------------ sampler
def test_splitmix_deterministic_and_stream_independent():
    a = SplitMix64(derive_seed(7, "comm", 3))
    b = SplitMix64(derive_seed(7, "comm", 3))
    c = SplitMix64(derive_seed(7, "comm", 4))
    seq_a = [a.next_u64() for _ in range(8)]
    seq_b = [b.next_u64() for _ in range(8)]
    seq_c = [c.next_u64() for _ in range(8)]
    assert seq_a == seq_b
    assert seq_a != seq_c
    assert all(0.0 <= SplitMix64(i).uniform() < 1.0 for i in range(100))


def test_dist_discrete_roundtrip_and_mean():
    d = Dist.from_counter({64.0: 3, 128.0: 1})
    assert d.kind == "discrete"
    assert d.mean() == pytest.approx(80.0)
    d2 = Dist.from_dict(d.to_dict())
    rng = SplitMix64(1)
    samples = [d2.sample(rng) for _ in range(400)]
    assert set(samples) == {64.0, 128.0}
    # inverse-CDF over counts: ~3:1 ratio
    assert 0.6 < samples.count(64.0) / len(samples) < 0.9


def test_dist_binned_preserves_mean():
    counter = {float(i): 1 for i in range(1000)}     # >64 distinct -> binned
    d = Dist.from_counter(counter)
    assert d.kind == "binned"
    assert d.mean() == pytest.approx(499.5)
    rng = SplitMix64(9)
    est = sum(d.sample(rng) for _ in range(4000)) / 4000
    assert est == pytest.approx(499.5, rel=0.05)


# ------------------------------------------------------------------ profile
def test_profile_columnar_equals_node_path(tmp_path):
    et = moe_mixed_collectives(iters=30, ranks=8)
    p4 = str(tmp_path / "t4.chkb")
    save(et, p4, version=4)
    via_columns = profile_chkb([p4])
    via_nodes = profile_traces([et])
    a = json.loads(via_columns.to_json_bytes())
    b = json.loads(via_nodes.to_json_bytes())
    a["source"] = b["source"] = None          # file list differs, rest must not
    assert a == b


def test_profile_json_roundtrip_and_fingerprint(tmp_path):
    prof = profile_traces(_dp_traces())
    path = str(tmp_path / "p.json")
    prof.save(path)
    back = WorkloadProfile.load(path)
    assert back.to_json_bytes() == prof.to_json_bytes()
    assert back.fingerprint() == prof.fingerprint()
    assert back.symmetric
    assert set(back.rank_fingerprints) == {str(r) for r in range(8)}


def test_profile_determinism_byte_identical():
    a = profile_traces(_dp_traces()).to_json_bytes()
    b = profile_traces(_dp_traces()).to_json_bytes()
    assert a == b


def test_profile_fingerprint_location_independent(tmp_path):
    """Same trace bytes, different directory -> identical profile bytes,
    fingerprint, and synthesized CHKB (provenance must not leak into the
    determinism guarantee)."""
    et = dp_allreduce_pattern(steps=2, layers=4, ranks=4)
    profs = []
    for sub in ("a", "b"):
        d = tmp_path / sub
        d.mkdir()
        save(et, str(d / "t.chkb"), version=4)
        profs.append(profile_chkb([str(d / "t.chkb")]))
    pa, pb = profs
    assert pa.fingerprint() == pb.fingerprint()
    assert pa.to_json_bytes() == pb.to_json_bytes()
    ma = synthesize(pa, str(tmp_path / "sa"), world_size=2, steps=2,
                    ops_per_step=8, seed=0)
    mb = synthesize(pb, str(tmp_path / "sb"), world_size=2, steps=2,
                    ops_per_step=8, seed=0)
    for fa, fb in zip(ma["paths"], mb["paths"]):
        assert open(fa, "rb").read() == open(fb, "rb").read()


def test_profile_obfuscation_preserves_structure():
    prof = profile_traces(_dp_traces())
    obf = prof.obfuscated_copy()
    assert obf.obfuscated
    assert obf.category_mix == prof.category_mix
    assert obf.fan_in.to_dict() == prof.fan_in.to_dict()
    for cat in prof.name_pools:
        originals = {t for t, _ in prof.name_pools[cat]}
        hashed = {t for t, _ in obf.name_pools[cat]}
        assert not originals & hashed          # no source name survives
        assert all(t.startswith("x") and t.endswith("*") for t in hashed)
    assert obf.to_dict()["source"]["files"] == []


def test_profile_asymmetric_ranks_detected():
    t0 = dp_allreduce_pattern(steps=2, layers=4, ranks=2, rank=0)
    t1 = dp_allreduce_pattern(steps=4, layers=4, ranks=2, rank=1)
    prof = profile_traces([t0, t1])
    assert not prof.symmetric


# ---------------------------------------------------------------- generator
def test_generate_ranks_coherent_and_zero_orphans():
    traces = _moe_traces(ranks=8, iters=10)
    res = Simulator(traces, Fabric.build("switch", 8)).run()
    comm_per_rank = [len(t.comm_nodes()) for t in traces]
    assert len(set(comm_per_rank)) == 1
    # zero orphans: every collective across every rank matched into a flow
    assert len(res.flows) == comm_per_rank[0]
    assert res.makespan_s > 0


def test_generate_ranks_rejects_divergent_pattern():
    def divergent(rank=0, ranks=4):
        et = ExecutionTrace(rank=rank, world_size=ranks)
        pg = et.add_process_group(list(range(ranks)), tag="x")
        et.add_node(name="ar", type=NodeType.COMM_COLL,
                    comm_type=CollectiveType.ALL_REDUCE, comm_group=pg.id,
                    comm_bytes=1024 * (rank + 1))       # rank-dependent!
        return et

    with pytest.raises(ValueError, match="rank-coherent"):
        generate_ranks(divergent, ranks=4)


def test_generate_ranks_no_rank_param_pattern():
    traces = generate_ranks("compute_chain", ranks=3, n=5)
    assert [t.rank for t in traces] == [0, 1, 2]
    assert all(t.world_size == 3 for t in traces)


# ---------------------------------------------------------------- synthesis
def test_synth_nodes_are_canonical_dag():
    prof = profile_traces(_dp_traces())
    last = -1
    for node in iter_rank_nodes(prof, rank=0, steps=4,
                                ops_per_step=32, seed=5):
        assert node.id == last + 1
        last = node.id
        for dep, _ in node.all_deps():
            assert dep < node.id           # only backwards edges: acyclic
    assert last >= 0


def test_synth_deterministic_byte_identical(tmp_path):
    prof = profile_traces(_dp_traces())
    kw = dict(world_size=4, steps=6, ops_per_step=24, seed=11)
    m1 = synthesize(prof, str(tmp_path / "a"), **kw)
    m2 = synthesize(prof, str(tmp_path / "b"), **kw)
    for pa, pb in zip(m1["paths"], m2["paths"]):
        with open(pa, "rb") as fa, open(pb, "rb") as fb:
            assert fa.read() == fb.read()
    m3 = synthesize(prof, str(tmp_path / "c"), world_size=4, steps=6,
                    ops_per_step=24, seed=12)
    with open(m1["paths"][0], "rb") as fa, open(m3["paths"][0], "rb") as fb:
        assert fa.read() != fb.read()      # the seed actually matters


def test_synth_multirank_rendezvous_zero_orphans(tmp_path):
    prof = profile_traces(_moe_traces())
    man = synthesize(prof, str(tmp_path / "s"), world_size=8, steps=6,
                     ops_per_step=32, seed=2,
                     stragglers={1: 2.0}, jitter=0.25)
    traces = [load(p) for p in man["paths"]]
    comm_counts = [len(t.comm_nodes()) for t in traces]
    assert len(set(comm_counts)) == 1
    res = Simulator(traces, Fabric.build("switch", 8)).run()
    assert len(res.flows) == comm_counts[0]   # every collective matched
    assert res.makespan_s > 0


def test_synth_scale_knobs(tmp_path):
    prof = profile_traces(_dp_traces())
    base = synthesize(prof, str(tmp_path / "base"), world_size=2, steps=4,
                      ops_per_step=32, seed=3)
    scaled = synthesize(prof, str(tmp_path / "scaled"), world_size=2, steps=4,
                        ops_per_step=32, seed=3, scale_comm_bytes=2.0,
                        scale_duration=3.0)
    sb = analysis.columnar_summary(base["paths"][0])
    ss = analysis.columnar_summary(scaled["paths"][0])
    assert ss["total_bytes"] == pytest.approx(2 * sb["total_bytes"])
    assert ss["sum_duration_us"] == pytest.approx(3 * sb["sum_duration_us"])
    # world_size scale-up: the process group covers the synthetic world
    big = synthesize(prof, str(tmp_path / "big"), world_size=64, steps=2,
                     ops_per_step=16, seed=3, ranks=[0, 63])
    t = load(big["paths"][1])
    assert t.rank == 63 and t.world_size == 64
    assert len(t.process_groups[0].ranks) == 64


def test_synth_bounded_memory_streaming(tmp_path):
    """A 100k-node rank streams through ChkbWriter without ever holding the
    node list: tracemalloc peak stays far below the materialized size."""
    prof = profile_traces(_dp_traces())
    path = str(tmp_path / "big.chkb")
    tracemalloc.start()
    row = synthesize_rank(prof, path, rank=0, world_size=8,
                          steps=250, ops_per_step=400, seed=1)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert row["nodes"] == 100_000
    with ChkbReader(path) as r:
        assert r.node_count == 100_000
        assert r.version == 4
    # materializing 100k ETNodes costs >40MB; the stream stays O(block)
    assert peak < 24 * 1024 * 1024


# ------------------------------------------------------------- closed loop
def test_closed_loop_fidelity_within_10pct(tmp_path):
    """ISSUE acceptance: profile a source ET set, synthesize >=8 coherent
    ranks via streamed CHKB v4, simulate them, and match the source profile's
    category mix and per-collective comm bytes within 10%."""
    source = _moe_traces(ranks=8, iters=40)
    prof = profile_traces(source)
    steps = 10
    ops = max(4, round(prof.nodes_per_rank / steps))
    man = synthesize(prof, str(tmp_path / "loop"), world_size=8, steps=steps,
                     ops_per_step=ops, seed=0)
    assert len(man["paths"]) == 8

    # --- category mix within 10% (fractions of the whole)
    src_mix = prof.category_mix
    src_total = sum(src_mix.values())
    synth_counts = {}
    synth_total = 0
    for p in man["paths"]:
        t = load(p)
        for cat, cnt in analysis.op_counts(t).items():
            synth_counts[cat] = synth_counts.get(cat, 0) + cnt
            synth_total += cnt
    for cat, cnt in src_mix.items():
        src_frac = cnt / src_total
        syn_frac = synth_counts.get(cat, 0) / synth_total
        assert syn_frac == pytest.approx(src_frac, abs=0.1 * max(src_frac, 0.05)), cat

    # --- per-collective comm bytes per node within 10% (columnar summary)
    src_comm = {}
    for t in source:
        for k, row in analysis.comm_summary(t).items():
            agg = src_comm.setdefault(k, {"count": 0, "bytes": 0.0})
            agg["count"] += row["count"]
            agg["bytes"] += row["bytes"]
    syn_comm = {}
    for p in man["paths"]:
        for k, row in analysis.columnar_summary(p)["comm_summary"].items():
            agg = syn_comm.setdefault(k, {"count": 0, "bytes": 0.0})
            agg["count"] += row["count"]
            agg["bytes"] += row["bytes"]
    assert set(syn_comm) == set(src_comm)
    for k in src_comm:
        src_mean = src_comm[k]["bytes"] / src_comm[k]["count"]
        syn_mean = syn_comm[k]["bytes"] / syn_comm[k]["count"]
        assert syn_mean == pytest.approx(src_mean, rel=0.1), k

    # --- and the synthesized fleet actually simulates, with zero orphans
    traces = [load(p) for p in man["paths"]]
    res = Simulator(traces, Fabric.build("switch", 8)).run()
    assert len(res.flows) == len(traces[0].comm_nodes())
    assert res.makespan_s > 0


# ---------------------------------------------------------------- scenarios
@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenario_profiles_synthesize_and_simulate(name, tmp_path):
    sc = get_scenario(name)
    prof = sc.profile()
    assert prof.fingerprint() == sc.profile().fingerprint()  # deterministic
    knobs = dict(sc.knobs)
    steps = min(int(knobs.pop("steps", 6)), 6)
    man = synthesize(prof, str(tmp_path / name), world_size=4, steps=steps,
                     ops_per_step=16, seed=1, **knobs)
    traces = [load(p) for p in man["paths"]]
    res = Simulator(traces, Fabric.build("switch", 4)).run()
    assert len(res.flows) == len(traces[0].comm_nodes())


def test_unknown_scenario_raises():
    with pytest.raises(ValueError, match="unknown scenario"):
        get_scenario("nope")


# ----------------------------------------------------------- registry / CLI
def test_synth_stages_registered():
    stages = available_stages()
    assert "synth.generate" in stages["source"]
    assert "synth.profile" in stages["sink"]
    assert "synth.profile" in stages["pass"]


def test_pipeline_synth_generate_source_streams(tmp_path):
    out = str(tmp_path / "gen.chkb")
    prof_path = str(tmp_path / "p.json")
    profile_traces(_dp_traces()).save(prof_path)
    path = (Pipeline.from_source("synth.generate", profile=prof_path,
                                 rank=0, world_size=4, steps=4,
                                 ops_per_step=16, seed=0, window=32)
            .sink("chkb", out).run())
    summary = analysis.columnar_summary(path)
    assert summary["nodes"] == 64
    assert summary["comm_summary"]          # collectives made it through


def test_pipeline_synth_profile_pass_and_sink(tmp_path):
    prof_path = str(tmp_path / "streamed.json")
    et = dp_allreduce_pattern(steps=2, layers=4, ranks=4)
    pipe = (Pipeline.from_source("trace", et)
            .then("synth.profile", path=prof_path)
            .sink("analyze"))
    stats = pipe.run()
    assert stats["nodes"] == len(et)
    streamed = WorkloadProfile.load(prof_path)
    direct = profile_traces([et])
    assert streamed.category_mix == direct.category_mix

    sink_prof = (Pipeline.from_source("trace", et)
                 .sink("synth.profile").run())
    assert sink_prof.category_mix == direct.category_mix


def test_profile_builder_multiple_files_one_profile(tmp_path):
    paths = []
    for t in _dp_traces(ranks=4)[:4]:
        p = str(tmp_path / f"r{t.rank}.chkb")
        save(t, p, version=4)
        paths.append(p)
    b = ProfileBuilder()
    for p in paths:
        b.add_chkb(p)
    prof = b.finish()
    assert len(prof.rank_fingerprints) == 4
    assert prof.symmetric
    # basenames only: provenance must not leak directory structure
    assert prof.to_dict()["source"]["files"] == [os.path.basename(p)
                                                 for p in paths]


def test_comm_categories_constant():
    assert "AllReduce" in COMM_CATEGORIES
    assert "GeMM" not in COMM_CATEGORIES
