"""Serving engine: prefill->decode vs parallel forward, MoE routing stats,
KV-offload accounting, P/D KV-transfer trace nodes."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import base as config_base
from repro.core import ExecutionTrace, NodeType
from repro.models import model_zoo
from repro.serve import Engine, ServeConfig


def _engine(arch, rng_key, **kw):
    cfg = config_base.get(arch).reduced()
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    model = model_zoo.build(cfg, model_axis=1)
    params = model.init(rng_key)
    return Engine(model, params, ServeConfig(max_len=32, **kw)), cfg


@pytest.mark.parametrize("arch", ["granite-8b", "mixtral-8x7b",
                                  "xlstm-1.3b"])
def test_prefill_matches_forward(arch, rng_key):
    eng, cfg = _engine(arch, rng_key)
    tokens = jax.random.randint(rng_key, (2, 8), 0, 100).astype(jnp.int32)
    logits, state = eng.prefill(tokens)
    full = eng.model.logits(eng.params, {"tokens": tokens})[:, -1]
    err = jnp.max(jnp.abs(logits - full.astype(jnp.float32)))
    rel = float(err) / (float(jnp.max(jnp.abs(full))) + 1e-6)
    assert rel < 0.05, rel


def test_generate_greedy_deterministic(rng_key):
    eng, cfg = _engine("granite-8b", rng_key)
    tokens = jnp.ones((2, 4), jnp.int32)
    out1 = eng.generate(tokens, n_steps=5)
    out2 = eng.generate(tokens, n_steps=5)
    assert out1.shape == (2, 5)
    assert bool(jnp.all(out1 == out2))


def test_moe_routing_stats_recorded(rng_key):
    et = ExecutionTrace()
    eng, cfg = _engine("olmoe-1b-7b", rng_key, trace=et)
    tokens = jnp.ones((2, 4), jnp.int32)
    eng.generate(tokens, n_steps=3)
    assert len(eng.stats["moe_routing"]) == 3
    bins = eng.stats["moe_routing"][0]
    assert len(bins) == cfg.n_experts
    assert sum(bins) == 2 * cfg.top_k          # B tokens x top_k
    route_nodes = [n for n in et if n.attrs.get("op") == "moe_routing"]
    assert len(route_nodes) == 3


def test_kv_offload_accounting(rng_key):
    et = ExecutionTrace()
    eng, cfg = _engine("granite-8b", rng_key, offload_kv=True, trace=et)
    tokens = jnp.ones((2, 4), jnp.int32)
    eng.generate(tokens, n_steps=3)
    assert eng.stats["memcpy_dtoh"] == 3
    assert eng.stats["memcpy_htod"] == 3
    stores = [n for n in et if n.attrs.get("op") == "start_store_kv"]
    loads = [n for n in et if n.attrs.get("op") == "start_load_kv"]
    assert len(stores) == 3 and len(loads) == 3
    assert all(n.comm_bytes > 0 for n in stores)


def test_kv_transfer_trace_fig15(rng_key):
    et = ExecutionTrace()
    eng, cfg = _engine("granite-8b", rng_key, trace=et)
    eng.prefill(jnp.ones((2, 4), jnp.int32))
    sizes = eng.stats["kv_transfer_bytes"]
    assert len(sizes) == 2 * cfg.n_layers        # k and v per layer
    xfer = [n for n in et if n.attrs.get("op") == "kv_transfer"]
    assert len(xfer) == len(sizes)
    assert all(n.type == NodeType.COMM_SEND for n in xfer)
