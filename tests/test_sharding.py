"""Logical-axis sharding rules: divisibility guard, axis reuse, specs."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import default_rules, spec_for


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _spec(shape, logical, rules, sizes):
    """spec_for against a fake mesh with given axis sizes."""
    class FakeMesh:
        def __init__(self, sizes):
            self.shape = sizes
    return spec_for(shape, logical, rules, FakeMesh(sizes))


RULES = default_rules(multi_pod=False)
SIZES = {"data": 16, "model": 16}


def test_divisible_dims_shard():
    sp = _spec((256, 4096, 4096), ("batch", None, "embed"), RULES, SIZES)
    assert sp == P(("data",))                 # embed -> None by rule


def test_non_divisible_dims_replicate():
    # hymba: 25 heads % 16 != 0 -> replicated, no special case needed
    sp = _spec((2, 128, 25, 64), ("batch", None, "heads", None), RULES, SIZES)
    assert sp == P()                          # batch 2 % 16 != 0 too
    sp = _spec((32, 128, 32, 64), ("batch", None, "heads", None), RULES,
               SIZES)
    assert sp == P(("data",), None, "model")


def test_axis_used_once():
    # kv_seq and heads both map to "model": first dim wins, second drops
    sp = _spec((128, 32768, 32, 128), ("batch", "kv_seq", "heads", None),
               RULES, SIZES)
    assert sp == P(("data",), "model")


def test_multi_pod_batch_axes():
    rules = default_rules(multi_pod=True)
    sizes = {"pod": 2, "data": 16, "model": 16}
    sp = _spec((256, 4096), ("batch", "seq"), rules, sizes)
    assert sp == P(("pod", "data"), "model")


def test_trailing_nones_trimmed():
    sp = _spec((64, 64), (None, None), RULES, SIZES)
    assert sp == P()
